"""Fault-tolerance subsystem (DESIGN.md section 16): atomic writes,
crash-safe checkpoint/resume for solves and path sweeps (bit-exact, and
across device counts — the checkpoints are mesh-agnostic host arrays),
the engine's non-finite detector + rollback, automatic P-backoff toward
the certified safe bundle size, the deterministic fault-injection
harness, and the CLI kill-resume path (a SIGKILL'd sweep resumed with
--resume produces the same artifact as the uninterrupted run)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core import PCDNConfig, make_problem, with_bundle_size
from repro.data import make_classification
from repro.engine import (LocalBackend, ShardedBackend, ShardedPCDNConfig,
                          loop as engine_loop)
from repro import fault
from repro.fault import atomic
from repro.path.driver import PathConfig, run_path

# tol reachable at EVERY bundle size the backoff schedule can visit:
# a backed-off retry (P=16 on this problem) plateaus above 1e-4 in f32,
# so rollback tests must not demand the high-P tolerance.
TOL = 1e-3


@pytest.fixture(scope="module")
def data():
    return make_classification(300, 128, sparsity=0.8, corr=0.3, seed=2)


@pytest.fixture(scope="module")
def prob(data):
    X, y, _ = data
    return make_problem(X, y, c=1.0)


def _factory(prob, **kw):
    cfg = PCDNConfig(P=32, max_outer=80, tol_kkt=TOL, **kw)

    def factory(P):
        return LocalBackend(prob, with_bundle_size(cfg, P))
    return factory


# -- atomic writes ------------------------------------------------------------

def test_atomic_write_roundtrip(tmp_path):
    p = str(tmp_path / "a.json")
    atomic.atomic_write_json(p, {"x": 1})
    assert json.load(open(p)) == {"x": 1}
    atomic.atomic_write_text(str(tmp_path / "t.txt"), "hi")
    assert open(tmp_path / "t.txt").read() == "hi"


def test_atomic_write_never_tears(tmp_path):
    """A failed write leaves the previous contents AND no tmp debris —
    the torn-file regression for the serve artifact hot-swap watcher."""
    p = str(tmp_path / "model.json")
    atomic.atomic_write_json(p, {"good": True})
    with pytest.raises(TypeError):
        atomic.atomic_write_json(p, {"bad": object()})   # unserializable
    assert json.load(open(p)) == {"good": True}          # intact
    assert [f for f in os.listdir(tmp_path)
            if f.startswith(".tmp-")] == []              # no debris


def test_save_model_is_atomic(tmp_path):
    """serve.artifact.save_model goes through the atomic writer: a
    reserved-key clash raises BEFORE the old artifact is disturbed."""
    from repro.serve import artifact as art
    rng = np.random.default_rng(0)
    w = np.zeros(32)
    w[rng.choice(32, 4, replace=False)] = 1.0
    fam = art.ModelFamily(kind="binary", models=(
        art.artifact_from_solution(w, "logistic", c=1.0),))
    p = str(tmp_path / "m.json")
    art.save_model(p, fam)
    good = open(p).read()
    with pytest.raises(ValueError, match="collide"):
        art.save_model(p, fam, extra={"models": []})
    assert open(p).read() == good
    assert art.load_model(p).n_features == 32


# -- fault plan / injection harness -------------------------------------------

def test_fault_plan_validation():
    with pytest.raises(ValueError, match="crash_kind"):
        fault.FaultPlan(crash_kind="nope")
    with pytest.raises(ValueError, match="nan_target"):
        fault.FaultPlan(nan_target="gradient")


def test_plan_from_env(monkeypatch):
    monkeypatch.delenv(fault.ENV_VAR, raising=False)
    assert fault.plan_from_env() is None
    monkeypatch.setenv(fault.ENV_VAR,
                       '{"crash_at_point": 2, "crash_kind": "sigkill"}')
    plan = fault.plan_from_env()
    assert plan.crash_at_point == 2 and plan.crash_kind == "sigkill"
    monkeypatch.setenv(fault.ENV_VAR, '{"typo_at_iter": 1}')
    with pytest.raises(ValueError, match="unknown keys"):
        fault.plan_from_env()
    monkeypatch.setenv(fault.ENV_VAR, '[1, 2]')
    with pytest.raises(ValueError, match="JSON object"):
        fault.plan_from_env()


def test_injection_fires_once():
    plan = fault.FaultPlan(crash_at_iter=1)
    calls = {"n": 0}

    def outer(w, z, key, active, recheck, c):
        calls["n"] += 1
        return ("w", "z", "key", 0.0, 0.0, 0, 0.0, "active", 0)

    wrapped = fault.wrap_outer(outer, plan)
    args = (None, None, None, None, True, 1.0)
    wrapped(*args)                       # k=0: clean
    with pytest.raises(fault.InjectedCrash):
        wrapped(*args)                   # k=1: crash
    # re-wrap from the redo point, same plan: the hook already fired
    rewrapped = fault.wrap_outer(outer, plan, start_iter=1)
    rewrapped(*args)                     # k=1 again: clean now
    assert calls["n"] == 2


def test_next_bundle_size_schedule():
    assert fault.next_bundle_size(32) == 16
    assert fault.next_bundle_size(1) == 1
    assert fault.next_bundle_size(256, p_cert=48) == 128   # plain halving
    assert fault.next_bundle_size(64, p_cert=48) == 48     # certified floor
    assert fault.next_bundle_size(32, p_cert=48) == 16     # already below
    assert fault.next_bundle_size(2, p_cert=0) == 1        # degenerate cert


# -- engine non-finite detector -----------------------------------------------

def test_nan_guard_local(prob):
    """NaN injected into margins mid-solve: the engine STOPS at that
    iteration (today's divergence_guard(f) with f=NaN compares False and
    would loop to max_outer) and hands back the LAST GOOD iterate."""
    backend = LocalBackend(prob, PCDNConfig(P=32, max_outer=80,
                                            tol_kkt=TOL))
    plan = fault.FaultPlan(nan_at_iter=3, nan_target="margins")
    outer = fault.wrap_outer(backend.outer, plan)
    state, res = engine_loop.run_outer_loop(
        outer, backend.init_state(), 1.0, max_outer=80, tol_kkt=TOL)
    assert res.nonfinite and res.diverged and not res.converged
    assert int(res.history.outer_iter[-1]) == 3       # stopped right there
    assert np.isfinite(res.objective)                 # last GOOD objective
    assert np.all(np.isfinite(np.asarray(state.w)))   # rolled-back carry
    assert np.all(np.isfinite(np.asarray(state.z)))
    assert res.postmortem is not None                 # PR 9 forensics rode


def test_nan_guard_sharded_1x1(data):
    X, y, _ = data
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    backend = ShardedBackend(X, y, mesh,
                             ShardedPCDNConfig(P_local=32, c=1.0,
                                               tol_kkt=TOL))
    plan = fault.FaultPlan(nan_at_iter=2, nan_target="margins")
    outer = fault.wrap_outer(backend.outer, plan)
    state, res = engine_loop.run_outer_loop(
        outer, backend.init_state(), 1.0, max_outer=60, tol_kkt=TOL)
    assert res.nonfinite and res.diverged
    assert np.all(np.isfinite(backend.host_weights(state.w)))


def test_nan_guard_kkt_only(prob):
    """A NaN that reaches only the KKT scalar still trips the detector."""
    backend = LocalBackend(prob, PCDNConfig(P=32, max_outer=40,
                                            tol_kkt=TOL))
    plan = fault.FaultPlan(nan_at_iter=1, nan_target="kkt")
    state, res = engine_loop.run_outer_loop(
        fault.wrap_outer(backend.outer, plan), backend.init_state(), 1.0,
        max_outer=40, tol_kkt=TOL)
    assert res.nonfinite
    assert int(res.history.outer_iter[-1]) == 1


# -- rollback + P-backoff -----------------------------------------------------

def test_resilient_clean_solve_matches_plain(prob):
    factory = _factory(prob)
    plain = engine_loop.solve(factory(32), 1.0, max_outer=80, tol_kkt=TOL)
    res = fault.resilient_solve(factory, 1.0, P=32, max_outer=80,
                                tol_kkt=TOL)
    assert res.converged and res.faults is None
    np.testing.assert_array_equal(np.asarray(plain.w), res.w)


def test_rollback_backoff_converges(prob):
    """The acceptance scenario: NaN into margins mid-solve -> rollback,
    P halves toward the certified bound, and the retried solve still
    converges to the same KKT tolerance."""
    factory = _factory(prob)
    plan = fault.FaultPlan(nan_at_iter=3, nan_target="margins")
    res = fault.resilient_solve(factory, 1.0, P=32, max_outer=80,
                                tol_kkt=TOL, plan=plan, design=prob.design)
    assert res.converged
    assert res.faults["rollbacks"] == 1
    assert res.faults["p_schedule"] == [32, 16]
    assert res.faults["p_cert"] is not None
    assert float(res.history.kkt[-1]) <= TOL
    # the merged history is one contiguous global-iteration record
    assert (np.diff(np.asarray(res.history.outer_iter)) == 1).all()


def test_rollback_respects_certified_floor(prob):
    assert fault.next_bundle_size(32, p_cert=20) == 20
    factory = _factory(prob)
    plan = fault.FaultPlan(nan_at_iter=2, nan_target="weights")
    res = fault.resilient_solve(factory, 1.0, P=32, max_outer=80,
                                tol_kkt=TOL, plan=plan, p_cert=20)
    assert res.converged
    assert res.faults["p_schedule"] == [32, 20]


def test_rollback_retries_exhausted_surfaces_postmortem(prob):
    factory = _factory(prob)
    plan = fault.FaultPlan(nan_at_iter=3, nan_target="margins")
    res = fault.resilient_solve(factory, 1.0, P=32, max_outer=80,
                                tol_kkt=TOL, plan=plan, max_retries=0)
    assert res.nonfinite and not res.converged
    assert res.faults["rollbacks"] == 1
    assert np.isfinite(res.objective)        # still the last good iterate
    assert np.all(np.isfinite(res.w))


# -- solve checkpoint / resume ------------------------------------------------

def test_solve_checkpoint_resume_bit_exact(prob, tmp_path):
    factory = _factory(prob)
    ref = fault.resilient_solve(factory, 1.0, P=32, max_outer=80,
                                tol_kkt=TOL,
                                checkpointer=fault.SolveCheckpointer(
                                    str(tmp_path / "ref"), every=2))
    plan = fault.FaultPlan(crash_at_iter=3, crash_kind="exception")
    ck = fault.SolveCheckpointer(str(tmp_path / "x"), every=2)
    with pytest.raises(fault.InjectedCrash):
        fault.resilient_solve(factory, 1.0, P=32, max_outer=80,
                              tol_kkt=TOL, checkpointer=ck, plan=plan)
    res = fault.resilient_solve(
        factory, 1.0, P=32, max_outer=80, tol_kkt=TOL,
        checkpointer=fault.SolveCheckpointer(str(tmp_path / "x"), every=2),
        resume=True)
    assert res.converged
    assert res.faults["resumed_from"] is not None
    np.testing.assert_array_equal(ref.w, res.w)


def test_corrupted_checkpoints_skipped(prob, tmp_path):
    """Both damage modes are survived: a step missing COMMITTED (crash
    between write and commit) is invisible; a committed step whose
    arrays were later corrupted falls back to the previous one."""
    factory = _factory(prob)
    d = str(tmp_path / "ck")
    ref = fault.resilient_solve(factory, 1.0, P=32, max_outer=80,
                                tol_kkt=TOL,
                                checkpointer=fault.SolveCheckpointer(
                                    d, every=1, keep=10))
    mgr = fault.CheckpointManager(d)
    steps = mgr.steps()
    assert len(steps) >= 3
    fault.corrupt_checkpoint(d, step=steps[-1], mode="truncate")
    fault.corrupt_checkpoint(d, step=steps[-2], mode="uncommit")
    assert mgr.steps() == [s for s in steps if s != steps[-2]]
    got = mgr.restore_latest_valid_raw()
    assert got is not None
    step, _leaves, meta = got
    assert step == steps[-3]                 # skipped both damaged ones
    res = fault.resilient_solve(
        factory, 1.0, P=32, max_outer=80, tol_kkt=TOL,
        checkpointer=fault.SolveCheckpointer(d, every=1, keep=10),
        resume=True)
    assert res.converged
    np.testing.assert_array_equal(ref.w, res.w)


def test_solve_and_path_checkpoints_do_not_mix(prob, tmp_path):
    d = str(tmp_path / "ck")
    factory = _factory(prob)
    fault.resilient_solve(factory, 1.0, P=32, max_outer=80, tol_kkt=TOL,
                          checkpointer=fault.SolveCheckpointer(d, every=2))
    ck = fault.SolveCheckpointer(d, every=2)
    with pytest.raises(ValueError, match="separate --ckpt-dir"):
        ck.restore_path(factory(32), cs=np.asarray([1.0]), c_max=1.0)


def test_checkpointer_rejects_bad_cadence(tmp_path):
    with pytest.raises(ValueError, match=">= 1"):
        fault.SolveCheckpointer(str(tmp_path), every=0)


# -- path sweep checkpoint / resume -------------------------------------------

def _path_cfg():
    return PathConfig(solver=PCDNConfig(P=32, max_outer=60, tol_kkt=TOL),
                      n_points=5, span=30.0)


def test_path_crash_resume_bit_exact(prob, data, tmp_path):
    X, y, _ = data
    ref = run_path(prob, _path_cfg(), val_design=X, val_y=y)
    ck = fault.SolveCheckpointer(str(tmp_path / "p"), every=10)
    plan = fault.FaultPlan(crash_at_point=2, crash_kind="exception")
    with pytest.raises(fault.InjectedCrash):
        run_path(prob, _path_cfg(), val_design=X, val_y=y, ckpt=ck,
                 fault_plan=plan)
    res = run_path(prob, _path_cfg(), val_design=X, val_y=y,
                   ckpt=fault.SolveCheckpointer(str(tmp_path / "p"),
                                                every=10),
                   resume=True)
    np.testing.assert_array_equal(ref.weights, res.weights)
    assert res.best_index == ref.best_index
    assert [p.objective for p in res.points] == \
        [p.objective for p in ref.points]


def test_path_resume_rejects_different_grid(prob, data, tmp_path):
    X, y, _ = data
    ck = fault.SolveCheckpointer(str(tmp_path / "p"), every=10)
    run_path(prob, _path_cfg(), ckpt=ck)
    other = PathConfig(solver=PCDNConfig(P=32, max_outer=60, tol_kkt=TOL),
                       n_points=7, span=30.0)
    with pytest.raises(ValueError, match="different c-grid"):
        run_path(prob, other,
                 ckpt=fault.SolveCheckpointer(str(tmp_path / "p"),
                                              every=10),
                 resume=True)


# -- cross-device-count restore -----------------------------------------------

RESHARD_SCRIPT = r"""
import numpy as np, jax
from repro.data import make_classification
from repro.engine import (LocalBackend, ShardedBackend, ShardedPCDNConfig,
                          loop as engine_loop)
from repro.core import PCDNConfig, make_problem
from repro.fault import SolveCheckpointer, host_state

X, y, _ = make_classification(256, 64, sparsity=0.8, corr=0.3, seed=5)
assert len(jax.devices()) == 8

# writer: a 2x1 mesh runs 6 iterations and checkpoints every 3rd
cfg = ShardedPCDNConfig(P_local=16, c=1.0, tol_kkt=1e-3)
wb = ShardedBackend(X, y, jax.make_mesh((2, 1), ("data", "model")), cfg)
ck = SolveCheckpointer("CKDIR", every=3)
st, res = engine_loop.run_outer_loop(
    wb.outer, wb.init_state(), 1.0, max_outer=6, tol_kkt=0.0,
    state_callback=ck.solve_callback(wb))
snap5 = ck.manager.load_raw(5)     # the host image of iteration 5

# reader 1: a DIFFERENT device count (4x2 mesh) restores the snapshot
rb = ShardedBackend(X, y, jax.make_mesh((4, 2), ("data", "model")), cfg)
st4, meta = SolveCheckpointer("CKDIR", every=3).restore_solve(rb)
assert meta["outer_iter"] == 5
got = host_state(rb, st4)
for k in ("w", "z", "active", "key"):
    np.testing.assert_array_equal(snap5[k], got[k]), k
# ...and actually keeps solving from there (finite, global indices)
st4, r4 = engine_loop.run_outer_loop(
    rb.outer, st4, 1.0, max_outer=9, tol_kkt=0.0, start_iter=6)
assert np.isfinite(r4.objective) and r4.n_outer == 9
assert list(r4.history.outer_iter) == [6, 7, 8]

# reader 2: the LOCAL backend restores the same mesh-agnostic snapshot
prob = make_problem(X, y, c=1.0)
lb = LocalBackend(prob, PCDNConfig(P=16, max_outer=12, tol_kkt=1e-3))
stl, meta = SolveCheckpointer("CKDIR", every=3).restore_solve(lb)
assert meta["outer_iter"] == 5
np.testing.assert_array_equal(snap5["w"], np.asarray(stl.w))
print("ENGINE_OK")
"""


def test_resume_across_device_counts(tmp_path):
    """A checkpoint written on a 2-device mesh restores bit-exactly onto
    an 8-device (4x2) mesh AND onto the local backend, then keeps
    solving: the snapshot is unpadded host arrays, so the device count
    is not part of the format."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["REPRO_AUTOTUNE"] = "off"
    script = RESHARD_SCRIPT.replace("CKDIR", str(tmp_path / "ck"))
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ENGINE_OK" in out.stdout


# -- CLI kill-resume ----------------------------------------------------------

def _cli(args, env=None, **kw):
    e = dict(os.environ)
    e["REPRO_AUTOTUNE"] = "off"
    if env:
        e.update(env)
    return subprocess.run([sys.executable, "-m"] + args,
                          capture_output=True, text=True, env=e,
                          timeout=600, **kw)


def test_cli_sigkill_path_sweep_resumes_to_same_artifact(tmp_path):
    """THE acceptance scenario end-to-end through the real CLI: a path
    sweep SIGKILL'd mid-run (REPRO_FAULT_PLAN, no test-only flags)
    resumed with --resume produces the identical report — same best-c
    pick, objectives matching the uninterrupted run exactly."""
    base = ["repro.launch.path", "--dataset", "a9a", "--points", "3",
            "--P", "64", "--max-outer", "15", "--tol", "1e-3"]
    ref = _cli(base + ["--out", str(tmp_path / "ref.json")])
    assert ref.returncode == 0, ref.stderr[-4000:]
    killed = _cli(base + ["--ckpt-dir", str(tmp_path / "ck")],
                  env={"REPRO_FAULT_PLAN":
                       '{"crash_at_point": 1, "crash_kind": "sigkill"}'})
    assert killed.returncode == -9          # SIGKILL, not a clean exit
    assert (tmp_path / "ck").is_dir()
    resumed = _cli(base + ["--ckpt-dir", str(tmp_path / "ck"), "--resume",
                           "--out", str(tmp_path / "res.json")])
    assert resumed.returncode == 0, resumed.stderr[-4000:]
    assert "resuming path sweep at point 2/3" in resumed.stdout
    a = json.load(open(tmp_path / "ref.json"))
    b = json.load(open(tmp_path / "res.json"))
    assert a["best_index"] == b["best_index"]
    for pa, pb in zip(a["points"], b["points"]):
        rel = abs(pa["objective"] - pb["objective"]) / abs(pa["objective"])
        assert rel <= 1e-6
        assert pa["nnz"] == pb["nnz"]


def test_cli_solve_resume_continues(tmp_path):
    out1 = _cli(["repro.launch.solve", "--dataset", "a9a", "--P", "64",
                 "--max-outer", "8", "--tol", "1e-6",
                 "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "3"])
    assert out1.returncode == 0, out1.stderr[-4000:]
    out2 = _cli(["repro.launch.solve", "--dataset", "a9a", "--P", "64",
                 "--max-outer", "16", "--tol", "1e-6",
                 "--ckpt-dir", str(tmp_path / "ck"), "--resume"])
    assert out2.returncode == 0, out2.stderr[-4000:]
    assert "resuming solve at outer iteration 6" in out2.stdout
    assert "resumed_from=5" in out2.stdout


def test_cli_flag_validation(tmp_path):
    bad = _cli(["repro.launch.solve", "--dataset", "a9a",
                "--solver", "scdn", "--ckpt-dir", str(tmp_path / "x")])
    assert bad.returncode != 0
    assert "--solver pcdn or cdn" in bad.stderr
    bad2 = _cli(["repro.launch.path", "--dataset", "a9a",
                 "--mode", "batch", "--ckpt-dir", str(tmp_path / "y")])
    assert bad2.returncode != 0
    assert "--mode sweep" in bad2.stderr
    bad3 = _cli(["repro.launch.solve", "--dataset", "a9a", "--resume"])
    assert bad3.returncode != 0
    assert "--ckpt-dir" in bad3.stderr
