"""Calibration tests for the trip-count-aware HLO cost model — guards the
empirical fact that XLA cost_analysis counts while bodies once."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import compat, hlo_cost
from repro.utils.hlo import Roofline


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_trip_count_multiplied():
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=8)
        return h

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = _compile(f, x, w)
    # XLA's own analysis counts the loop body once (the bug we fix);
    # compat.cost_analysis flattens the jax-0.4.x list-of-dicts return.
    assert compat.cost_analysis(compiled)["flops"] < 2 * 2 * 128 * 256 * 256
    mc = hlo_cost.analyze(compiled.as_text())
    assert abs(mc.flops - 8 * 2 * 128 * 256 * 256) / mc.flops < 1e-6
    assert 8 in mc.trip_counts.values()


def test_nested_scan():
    def g(x, w):
        def outer(h, _):
            def inner(h2, _):
                return jnp.tanh(h2 @ w), None
            h2, _ = jax.lax.scan(inner, h, None, length=3)
            return h2, None
        h, _ = jax.lax.scan(outer, x, None, length=4)
        return h

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    mc = hlo_cost.analyze(_compile(g, x, w).as_text())
    assert abs(mc.flops - 12 * 2 * 64 * 128 * 128) / mc.flops < 1e-6


def test_grad_flops_counted():
    def h(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=5)
        return out.sum()

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    mc = hlo_cost.analyze(_compile(jax.grad(h, argnums=1), x, w).as_text())
    # fwd 5 matmuls + bwd 2 matmuls per step
    expected = (5 + 10) * 2 * 128 * 256 * 256
    assert abs(mc.flops - expected) / expected < 1e-6


def test_unrolled_matches_scan():
    def f_scan(x, w):
        def body(h, _):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, None, length=6)
        return h

    def f_unroll(x, w):
        for _ in range(6):
            x = x @ w
        return x

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    m1 = hlo_cost.analyze(_compile(f_scan, x, w).as_text())
    m2 = hlo_cost.analyze(_compile(f_unroll, x, w).as_text())
    assert abs(m1.flops - m2.flops) / m2.flops < 1e-6


def test_roofline_terms():
    r = Roofline(flops=197e12 * 256, hbm_bytes=819e9 * 256,
                 coll_bytes=50e9 * 256 * 2, n_chips=256,
                 model_flops=197e12 * 128)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert abs(r.t_collective - 2.0) < 1e-9
    assert r.bottleneck == "collective"
    assert abs(r.useful_flops_ratio - 0.5) < 1e-9


def test_dot_attribution_sums_to_total():
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=4)
        return h

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    mc = hlo_cost.analyze(_compile(f, x, w).as_text())
    assert abs(sum(mc.dot_sources.values()) - mc.flops) / mc.flops < 1e-6
