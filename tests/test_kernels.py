"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, positive=False):
    a = RNG.standard_normal(shape)
    if positive:
        a = np.abs(a) + 0.01
    return jnp.asarray(a, dtype)


# -- pcdn_direction -----------------------------------------------------------

@pytest.mark.parametrize("s,P", [(64, 8), (512, 128), (1000, 37), (77, 5),
                                 (2048, 256), (33, 130)])
@pytest.mark.parametrize("l2", [0.0, 0.3])
def test_pcdn_direction_shapes(s, P, l2):
    XB = _arr((s, P))
    u = _arr((s,))
    v = _arr((s,), positive=True)
    w = _arr((P,))
    d1, g1, h1 = ops.pcdn_direction(XB, u, v, w, l2=l2)
    d2, g2, h2 = ref.pcdn_direction_ref(XB, u, v, w, l2=l2)
    np.testing.assert_allclose(g1, g2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h1, h2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(d1, d2, rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pcdn_direction_dtypes(dtype):
    XB = _arr((256, 64), dtype)
    u = _arr((256,), dtype)
    v = _arr((256,), dtype, positive=True)
    w = _arr((64,), dtype)
    d1, g1, h1 = ops.pcdn_direction(XB, u, v, w)
    d2, g2, h2 = ref.pcdn_direction_ref(XB.astype(jnp.float32),
                                        u.astype(jnp.float32),
                                        v.astype(jnp.float32),
                                        w.astype(jnp.float32))
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(d1, d2, rtol=tol, atol=tol)


# -- pcdn_sparse_direction ----------------------------------------------------

@pytest.mark.parametrize("s,P,k", [(64, 8, 4), (512, 128, 16), (300, 37, 9),
                                   (100, 130, 3)])
@pytest.mark.parametrize("l2", [0.0, 0.3])
def test_pcdn_sparse_direction_shapes(s, P, k, l2):
    rows = jnp.asarray(RNG.integers(0, s + 1, size=(P, k)), jnp.int32)
    vals = _arr((P, k)) * (rows < s)      # sentinel slots carry value 0
    u = _arr((s,))
    v = _arr((s,), positive=True)
    w = _arr((P,))
    d1, g1, h1 = ops.pcdn_sparse_direction(rows, vals, u, v, w, l2=l2)
    d2, g2, h2 = ref.pcdn_sparse_direction_ref(rows, vals, u, v, w, l2=l2)
    np.testing.assert_allclose(g1, g2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h1, h2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(d1, d2, rtol=2e-3, atol=2e-4)


def test_pcdn_sparse_direction_matches_dense_kernel():
    """Same bundle expressed both ways -> same (d, g, h)."""
    s, P = 128, 32
    X = np.asarray(_arr((s, P))) * (RNG.random((s, P)) < 0.1)
    XB = jnp.asarray(X, jnp.float32)
    k = max(1, int((X != 0).sum(axis=0).max()))
    rows = np.full((P, k), s, np.int64)
    vals = np.zeros((P, k), np.float32)
    for j in range(P):
        nz = np.nonzero(X[:, j])[0]
        rows[j, :len(nz)] = nz
        vals[j, :len(nz)] = X[nz, j]
    u = _arr((s,))
    v = _arr((s,), positive=True)
    w = _arr((P,))
    d1, g1, h1 = ops.pcdn_direction(XB, u, v, w)
    d2, g2, h2 = ops.pcdn_sparse_direction(
        jnp.asarray(rows, jnp.int32), jnp.asarray(vals), u, v, w)
    np.testing.assert_allclose(g1, g2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h1, h2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(d1, d2, rtol=2e-3, atol=2e-4)


# -- pcdn_linesearch ----------------------------------------------------------

@pytest.mark.parametrize("s", [64, 1000, 4096, 33])
@pytest.mark.parametrize("kind", ["logistic", "squared_hinge", "squared"])
def test_pcdn_linesearch_sweep(s, kind):
    z = _arr((s,))
    delta = _arr((s,))
    y = jnp.sign(_arr((s,))) if kind != "squared" else _arr((s,))
    alphas = jnp.asarray(0.5 ** np.arange(24), jnp.float32)
    o1 = ops.pcdn_linesearch(z, delta, y, alphas, kind=kind)
    o2 = ref.pcdn_linesearch_ref(z, delta, y, alphas, kind=kind)
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-3)


# -- flash attention ----------------------------------------------------------

@pytest.mark.parametrize("BH,Sq,Skv,D",
                         [(4, 128, 128, 64), (2, 256, 512, 128),
                          (1, 384, 384, 256), (3, 128, 256, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(BH, Sq, Skv, D, causal):
    q = _arr((BH, Sq, D))
    k = _arr((BH, Skv, D))
    v = _arr((BH, Skv, D))
    o1 = ops.flash_attention(q, k, v, causal)
    o2 = ref.attention_ref(q, k, v, causal)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16():
    q = _arr((2, 128, 64), jnp.bfloat16)
    k = _arr((2, 128, 64), jnp.bfloat16)
    v = _arr((2, 128, 64), jnp.bfloat16)
    o1 = ops.flash_attention(q, k, v, True)
    o2 = ref.attention_ref(q, k, v, True)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_attention_grad_matches_ref():
    q = _arr((2, 128, 64))
    k = _arr((2, 128, 64))
    v = _arr((2, 128, 64))

    def f1(q, k, v):
        return (ops.flash_attention(q, k, v, True) ** 2).sum()

    def f2(q, k, v):
        return (ref.attention_ref(q, k, v, True) ** 2).sum()

    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)
