"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, positive=False):
    a = RNG.standard_normal(shape)
    if positive:
        a = np.abs(a) + 0.01
    return jnp.asarray(a, dtype)


# -- pcdn_direction -----------------------------------------------------------

@pytest.mark.parametrize("s,P", [(64, 8), (512, 128), (1000, 37), (77, 5),
                                 (2048, 256), (33, 130)])
@pytest.mark.parametrize("l2", [0.0, 0.3])
def test_pcdn_direction_shapes(s, P, l2):
    XB = _arr((s, P))
    u = _arr((s,))
    v = _arr((s,), positive=True)
    w = _arr((P,))
    d1, g1, h1 = ops.pcdn_direction(XB, u, v, w, l2=l2)
    d2, g2, h2 = ref.pcdn_direction_ref(XB, u, v, w, l2=l2)
    np.testing.assert_allclose(g1, g2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h1, h2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(d1, d2, rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pcdn_direction_dtypes(dtype):
    XB = _arr((256, 64), dtype)
    u = _arr((256,), dtype)
    v = _arr((256,), dtype, positive=True)
    w = _arr((64,), dtype)
    d1, g1, h1 = ops.pcdn_direction(XB, u, v, w)
    d2, g2, h2 = ref.pcdn_direction_ref(XB.astype(jnp.float32),
                                        u.astype(jnp.float32),
                                        v.astype(jnp.float32),
                                        w.astype(jnp.float32))
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(d1, d2, rtol=tol, atol=tol)


# -- pcdn_sparse_direction ----------------------------------------------------

@pytest.mark.parametrize("s,P,k", [(64, 8, 4), (512, 128, 16), (300, 37, 9),
                                   (100, 130, 3)])
@pytest.mark.parametrize("l2", [0.0, 0.3])
def test_pcdn_sparse_direction_shapes(s, P, k, l2):
    rows = jnp.asarray(RNG.integers(0, s + 1, size=(P, k)), jnp.int32)
    vals = _arr((P, k)) * (rows < s)      # sentinel slots carry value 0
    u = _arr((s,))
    v = _arr((s,), positive=True)
    w = _arr((P,))
    d1, g1, h1 = ops.pcdn_sparse_direction(rows, vals, u, v, w, l2=l2)
    d2, g2, h2 = ref.pcdn_sparse_direction_ref(rows, vals, u, v, w, l2=l2)
    np.testing.assert_allclose(g1, g2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h1, h2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(d1, d2, rtol=2e-3, atol=2e-4)


def test_pcdn_sparse_direction_matches_dense_kernel():
    """Same bundle expressed both ways -> same (d, g, h)."""
    s, P = 128, 32
    X = np.asarray(_arr((s, P))) * (RNG.random((s, P)) < 0.1)
    XB = jnp.asarray(X, jnp.float32)
    k = max(1, int((X != 0).sum(axis=0).max()))
    rows = np.full((P, k), s, np.int64)
    vals = np.zeros((P, k), np.float32)
    for j in range(P):
        nz = np.nonzero(X[:, j])[0]
        rows[j, :len(nz)] = nz
        vals[j, :len(nz)] = X[nz, j]
    u = _arr((s,))
    v = _arr((s,), positive=True)
    w = _arr((P,))
    d1, g1, h1 = ops.pcdn_direction(XB, u, v, w)
    d2, g2, h2 = ops.pcdn_sparse_direction(
        jnp.asarray(rows, jnp.int32), jnp.asarray(vals), u, v, w)
    np.testing.assert_allclose(g1, g2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h1, h2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(d1, d2, rtol=2e-3, atol=2e-4)


# -- pcdn_linesearch ----------------------------------------------------------

@pytest.mark.parametrize("s", [64, 1000, 4096, 33])
@pytest.mark.parametrize("kind", ["logistic", "squared_hinge", "squared"])
def test_pcdn_linesearch_sweep(s, kind):
    z = _arr((s,))
    delta = _arr((s,))
    y = jnp.sign(_arr((s,))) if kind != "squared" else _arr((s,))
    alphas = jnp.asarray(0.5 ** np.arange(24), jnp.float32)
    o1 = ops.pcdn_linesearch(z, delta, y, alphas, kind=kind)
    o2 = ref.pcdn_linesearch_ref(z, delta, y, alphas, kind=kind)
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-3)


# -- flash attention ----------------------------------------------------------

@pytest.mark.parametrize("BH,Sq,Skv,D",
                         [(4, 128, 128, 64), (2, 256, 512, 128),
                          (1, 384, 384, 256), (3, 128, 256, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(BH, Sq, Skv, D, causal):
    q = _arr((BH, Sq, D))
    k = _arr((BH, Skv, D))
    v = _arr((BH, Skv, D))
    o1 = ops.flash_attention(q, k, v, causal)
    o2 = ref.attention_ref(q, k, v, causal)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16():
    q = _arr((2, 128, 64), jnp.bfloat16)
    k = _arr((2, 128, 64), jnp.bfloat16)
    v = _arr((2, 128, 64), jnp.bfloat16)
    o1 = ops.flash_attention(q, k, v, True)
    o2 = ref.attention_ref(q, k, v, True)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=5e-2, atol=5e-2)


# -- tiled launch configs (the autotuner's search axes) -----------------------
#
# Every tileable axis the autotuner may pick must be oracle-exact: tiling
# changes the launch decomposition, never the math.

@pytest.mark.parametrize("block_q", [1, 4, 8, 24, 100])
def test_pcdn_bundle_block_q_tiling(block_q):
    P, k, r, q = 24, 8, 96, 24
    rows = RNG.integers(0, r, size=(P, k))
    vals = _arr((P, k))
    pos = jnp.asarray(rows, jnp.int32)
    z = _arr((r,))
    y = jnp.sign(_arr((r,)))
    w = 0.1 * _arr((P,))
    alphas = jnp.asarray(0.5 ** np.arange(q), jnp.float32)
    args = (vals, pos, z, y, w, alphas, 1.0)
    uw1, uz1, a1, q1 = ops.pcdn_bundle(*args, block_q=block_q)
    uw2, uz2, a2, q2 = ref.pcdn_bundle_ref(*args)
    assert float(a1) == float(a2)
    assert int(q1) == int(q2)
    np.testing.assert_allclose(uw1, uw2, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(uz1, uz2, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("block_k", [4, 16, 64])
@pytest.mark.parametrize("block_p", [8, 32])
def test_pcdn_sparse_direction_block_k_tiling(block_k, block_p):
    s, P, k = 300, 37, 9
    rows = jnp.asarray(RNG.integers(0, s + 1, size=(P, k)), jnp.int32)
    vals = _arr((P, k)) * (rows < s)
    u = _arr((s,))
    v = _arr((s,), positive=True)
    w = _arr((P,))
    d1, g1, h1 = ops.pcdn_sparse_direction(rows, vals, u, v, w,
                                           block_p=block_p, block_k=block_k)
    d2, g2, h2 = ref.pcdn_sparse_direction_ref(rows, vals, u, v, w)
    np.testing.assert_allclose(g1, g2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h1, h2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(d1, d2, rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("block_s,block_p", [(64, 16), (1024, 256)])
def test_pcdn_direction_block_tiling(block_s, block_p):
    XB = _arr((500, 70))
    u = _arr((500,))
    v = _arr((500,), positive=True)
    w = _arr((70,))
    d1, g1, h1 = ops.pcdn_direction(XB, u, v, w, block_s=block_s,
                                    block_p=block_p)
    d2, g2, h2 = ref.pcdn_direction_ref(XB, u, v, w)
    np.testing.assert_allclose(g1, g2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(d1, d2, rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("block_s", [64, 512, 8192])
def test_pcdn_linesearch_block_tiling(block_s):
    s = 1000
    z = _arr((s,))
    delta = _arr((s,))
    y = jnp.sign(_arr((s,)))
    alphas = jnp.asarray(0.5 ** np.arange(20), jnp.float32)
    o1 = ops.pcdn_linesearch(z, delta, y, alphas, block_s=block_s)
    o2 = ref.pcdn_linesearch_ref(z, delta, y, alphas)
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("block_a", [16, 64, 1024])
@pytest.mark.parametrize("block_b", [8, 64])
def test_serve_margins_dense_block_a_tiling(block_a, block_b):
    B, n, K, A = 48, 256, 5, 96
    X = _arr((B, n))
    idx = jnp.asarray(np.stack([np.sort(RNG.permutation(n + 1)[:A])
                                for _ in range(K)]), jnp.int32)
    val = _arr((K, A)) * (idx < n)
    z1 = ops.serve_margins_dense(X, idx, val, block_b=block_b,
                                 block_a=block_a)
    z2 = ref.serve_margins_dense_ref(X, idx, val)
    np.testing.assert_allclose(z1, z2, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_impl_override_routes_both_ways(impl):
    """The impl axis is caller-forceable and both routes agree."""
    XB = _arr((128, 32))
    u = _arr((128,))
    v = _arr((128,), positive=True)
    w = _arr((32,))
    d1, g1, h1 = ops.pcdn_direction(XB, u, v, w, impl=impl)
    d2, g2, h2 = ref.pcdn_direction_ref(XB, u, v, w)
    np.testing.assert_allclose(d1, d2, rtol=2e-3, atol=2e-4)


def test_flash_attention_grad_matches_ref():
    q = _arr((2, 128, 64))
    k = _arr((2, 128, 64))
    v = _arr((2, 128, 64))

    def f1(q, k, v):
        return (ops.flash_attention(q, k, v, True) ** 2).sum()

    def f2(q, k, v):
        return (ref.attention_ref(q, k, v, True) ** 2).sum()

    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)
