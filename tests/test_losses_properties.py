"""Property tests for core/losses.py: the hand-written margin derivative
factors `dz` / `d2z` must match jax autodiff of `value`, and the
HESSIAN_FLOOR edge must keep Newton denominators positive where the true
curvature vanishes (paper footnote 1 / Lemma 1(b)).

Autodiff targets the PLAIN textbook forms (paper Eq. 2/3), not the
log1p/maximum-stabilized implementations: grad-of-stable-form has
spurious subgradient artifacts exactly at margin 0 (jnp.maximum /
jnp.abs tie-breaking) where the true losses are perfectly smooth.
Runs under `jax.experimental.enable_x64` (scoped, not global): in f32
the two only agree to ~eps at saturated margins, forcing vacuous
tolerances.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.experimental import enable_x64

from repro.core.losses import HESSIAN_FLOOR, get_loss
from repro.core.problem import make_problem

# margins away from exp overflow; labels are the +-1 contract
_Z = st.floats(-30.0, 30.0)
_Y = st.sampled_from([-1.0, 1.0])


def _plain_value(name):
    """The un-stabilized per-sample losses (paper Eq. 2/3 + Lasso)."""
    return {
        "logistic": lambda z, y: jnp.log1p(jnp.exp(-y * z)),
        "squared_hinge": lambda z, y: jnp.maximum(0.0, 1.0 - y * z) ** 2,
        "squared": lambda z, y: 0.5 * (z - y) ** 2,
    }[name]


def _check_scalar(name, z, y, rel=1e-5, abs_=1e-12):
    """dz/d2z at a scalar margin vs jax.grad of the plain form, in f64."""
    with enable_x64():
        loss = get_loss(name)
        plain = _plain_value(name)
        f = lambda zz: plain(zz, jnp.float64(y))
        g = float(jax.grad(f)(jnp.float64(z)))
        h = float(jax.grad(jax.grad(f))(jnp.float64(z)))
        # the stable implementation must also VALUE-match the plain form
        assert float(loss.value(jnp.float64(z), jnp.float64(y))) == \
            pytest.approx(float(f(jnp.float64(z))), rel=rel, abs=abs_)
        assert float(loss.dz(jnp.float64(z), jnp.float64(y))) == \
            pytest.approx(g, rel=rel, abs=abs_)
        assert float(loss.d2z(jnp.float64(z), jnp.float64(y))) == \
            pytest.approx(h, rel=rel, abs=abs_)


@settings(max_examples=60, deadline=None)
@given(_Z, _Y)
def test_logistic_dz_d2z_match_autodiff(z, y):
    _check_scalar("logistic", z, y)


@settings(max_examples=60, deadline=None)
@given(_Z, _Y)
def test_squared_hinge_dz_d2z_match_autodiff(z, y):
    """d2z is the GENERALIZED second derivative: it equals the autodiff
    Hessian everywhere except exactly at the kink y*z == 1, where the
    classical one does not exist — nudge off it (measure-zero set)."""
    if abs(1.0 - y * z) < 1e-6:
        z += 1e-3
    _check_scalar("squared_hinge", z, y)


@settings(max_examples=40, deadline=None)
@given(st.floats(-5.0, 5.0), st.floats(-5.0, 5.0))
def test_squared_loss_matches_autodiff(z, y):
    _check_scalar("squared", z, y)


@settings(max_examples=40, deadline=None)
@given(st.lists(_Z, min_size=2, max_size=16),
       st.lists(_Y, min_size=2, max_size=16),
       st.sampled_from(["logistic", "squared_hinge"]))
def test_vector_forms_match_hessian_diagonal(zs, ys, name):
    """The (s,)-vector dz/d2z are grad and the DIAGONAL of jax.hessian of
    the summed loss — the exact contract problem.grad/hess_factor uses;
    the off-diagonal curvature is zero by per-sample separability."""
    k = min(len(zs), len(ys))
    with enable_x64():
        z = jnp.asarray(zs[:k], jnp.float64)
        y = jnp.asarray(ys[:k], jnp.float64)
        if name == "squared_hinge":
            z = jnp.where(jnp.abs(1.0 - y * z) < 1e-6, z + 1e-3, z)
        loss = get_loss(name)
        plain = _plain_value(name)
        total = lambda zz: jnp.sum(plain(zz, y))
        g = np.asarray(jax.grad(total)(z))
        H = np.asarray(jax.hessian(total)(z))
        np.testing.assert_allclose(np.asarray(loss.dz(z, y)), g,
                                   rtol=1e-5, atol=1e-12)
        np.testing.assert_allclose(H,
                                   np.diag(np.asarray(loss.d2z(z, y))),
                                   rtol=1e-5, atol=1e-12)


def test_hessian_floor_edge():
    """Where the true curvature is exactly zero (L2-SVM with every margin
    satisfied), bundle_grad_hess must return h == HESSIAN_FLOOR > 0 so
    the Eq. 5 Newton step stays finite."""
    X = np.eye(4, dtype=np.float32)
    y = np.ones((4,), np.float32)
    prob = make_problem(X, y, c=1.0, loss="squared_hinge")
    w = jnp.full((4,), 5.0)            # margins z = 5 > 1: flat region
    z = prob.margins(w)
    assert float(jnp.max(prob.hess_factor(z))) == 0.0   # raw curvature 0
    slab = prob.design.gather_slab(jnp.arange(4, dtype=jnp.int32))
    g, h = prob.bundle_grad_hess(z, slab, w)
    np.testing.assert_allclose(np.asarray(h), HESSIAN_FLOOR, rtol=1e-6)
    assert bool(jnp.all(jnp.isfinite(-g / h)))


def test_hessian_floor_applies_under_x64_sweep():
    """Deterministic sweep version of the @given checks so the floor and
    derivative contracts stay covered even without hypothesis installed
    (the conftest stub skips @given tests in that case)."""
    for name in ("logistic", "squared_hinge", "squared"):
        for z in (-30.0, -2.0, -1e-3, 0.0, 0.5, 1.0 + 1e-3, 7.0, 30.0):
            for y in (-1.0, 1.0):
                if name == "squared_hinge" and abs(1.0 - y * z) < 1e-6:
                    continue
                _check_scalar(name, z, y)
