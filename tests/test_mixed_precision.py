"""Mixed-precision contract tests (DESIGN.md section 12): bf16 STORAGE
with f32 accumulation through the design matrix, the solver, the CLI
envelope gate, and the serving bank."""
import argparse

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PCDNConfig, make_problem, solve
from repro.core.design_matrix import as_design
from repro.launch import common
from repro.serve.predict import ModelBank, margins_dense

RNG = np.random.default_rng(7)


def _data(s=160, n=48, density=0.3):
    X = RNG.standard_normal((s, n)) * (RNG.random((s, n)) < density)
    w_true = RNG.standard_normal(n) * (RNG.random(n) < 0.5)
    y = np.sign(X @ w_true + 0.1 * RNG.standard_normal(s))
    y[y == 0] = 1.0
    return np.asarray(X, np.float32), np.asarray(y, np.float32)


# -- design matrix storage vs accumulation ------------------------------------


@pytest.mark.parametrize("layout", ["dense", "padded_csc"])
def test_design_bf16_storage_f32_results(layout):
    X, _ = _data()
    d32 = as_design(X, layout=layout)
    d16 = as_design(X, layout=layout, dtype=jnp.bfloat16)
    assert d16.acc_dtype == jnp.float32
    w = jnp.asarray(RNG.standard_normal(X.shape[1]), jnp.float32)
    u = jnp.asarray(RNG.standard_normal(X.shape[0]), jnp.float32)
    z32, z16 = d32.matvec(w), d16.matvec(w)
    assert z16.dtype == jnp.float32        # f32 accumulation, not bf16
    # bf16 storage rounds each VALUE once (~2^-8 relative); the reduction
    # itself stays f32, so the error is input-rounding-sized
    scale = float(np.abs(np.asarray(z32)).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(z16), np.asarray(z32),
                               atol=2e-2 * scale)
    g32 = d32.rmatvec(u)
    g16 = d16.rmatvec(u)
    assert g16.dtype == jnp.float32
    scale = float(np.abs(np.asarray(g32)).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(g16), np.asarray(g32),
                               atol=2e-2 * scale)


def test_problem_solve_dtype_pins_state_to_f32():
    X, y = _data()
    prob = make_problem(X, y, c=1.0, dtype=jnp.bfloat16)
    assert prob.solve_dtype == jnp.float32
    assert prob.y.dtype == jnp.float32


# -- matched-iteration trajectory equivalence ---------------------------------


@pytest.mark.parametrize("loss", ["logistic", "squared_hinge"])
def test_bf16_trajectory_matches_fp32(loss):
    """Same data, same config, tol_kkt=0 + fixed outer budget: iteration
    k of the bf16 run must track iteration k of the fp32 run to <= 1e-3
    relative objective — the envelope the --dtype bf16 gate promises."""
    X, y = _data()
    cfg = PCDNConfig(P=16, max_outer=10, tol_kkt=0.0, seed=0)
    objs = {}
    for name, dt in (("fp32", jnp.float32), ("bf16", jnp.bfloat16)):
        prob = make_problem(X, y, c=1.0, loss=loss, dtype=dt)
        res = solve(prob, cfg)
        objs[name] = np.asarray(res.history.objective, np.float64)
    n = min(len(objs["fp32"]), len(objs["bf16"]))
    assert n == 10
    rel = np.abs(objs["bf16"][:n] - objs["fp32"][:n]) / \
        np.maximum(np.abs(objs["fp32"][:n]), 1e-12)
    assert rel.max() <= 1e-3, f"max rel diff {rel.max():.2e}"


# -- CLI envelope gate --------------------------------------------------------


def _args(**kw):
    ns = argparse.Namespace(dtype="bf16", backend="local", tol=1e-3)
    for k, v in kw.items():
        setattr(ns, k, v)
    return ns


def _ap():
    return argparse.ArgumentParser()


def test_envelope_fp32_never_refused():
    common.check_dtype_envelope(_args(dtype="fp32", tol=1e-9,
                                      backend="sharded"), _ap(),
                                loss="squared")


def test_envelope_accepts_studied_configuration():
    common.check_dtype_envelope(_args(), _ap(), loss="logistic")
    common.check_dtype_envelope(_args(tol=0.01), _ap(),
                                loss="squared_hinge")


def test_envelope_refuses_sharded_backend():
    with pytest.raises(SystemExit):
        common.check_dtype_envelope(_args(backend="sharded"), _ap(),
                                    loss="logistic")


def test_envelope_refuses_unstudied_loss():
    with pytest.raises(SystemExit):
        common.check_dtype_envelope(_args(), _ap(), loss="squared")


def test_envelope_refuses_tight_tolerance():
    with pytest.raises(SystemExit):
        common.check_dtype_envelope(_args(tol=1e-5), _ap(),
                                    loss="logistic")


def test_solve_cli_refuses_bf16_outside_envelope():
    from repro.launch import solve as solve_cli
    with pytest.raises(SystemExit):
        solve_cli.main(["--dataset", "a9a", "--dtype", "bf16",
                        "--tol", "1e-6"])
    with pytest.raises(SystemExit):
        solve_cli.main(["--dataset", "a9a", "--dtype", "bf16",
                        "--backend", "sharded"])
    with pytest.raises(SystemExit):
        solve_cli.main(["--dataset", "a9a", "--dtype", "bf16",
                        "--solver", "tron"])


def test_path_cli_refuses_bf16_outside_envelope():
    from repro.launch import path as path_cli
    with pytest.raises(SystemExit):
        path_cli.main(["--dataset", "a9a", "--dtype", "bf16",
                       "--tol", "1e-6"])


def test_build_pcdn_config_records_dtype():
    cfg = common.build_pcdn_config(
        _args(P=32, max_outer=5, tol=1e-3, seed=0, shrink=False,
              use_kernels=False, ls_scope="auto", dtype="bf16"))
    assert cfg.dtype == "bfloat16"


# -- serving bank -------------------------------------------------------------


def test_bank_bf16_storage_f32_margins():
    W = np.asarray(RNG.standard_normal((4, 64)) *
                   (RNG.random((4, 64)) < 0.4), np.float32)
    X = np.asarray(RNG.standard_normal((16, 64)), np.float32)
    b32 = ModelBank.from_dense(W, kind="path")
    b16 = ModelBank.from_dense(W, kind="path", dtype=jnp.bfloat16)
    assert b16.val.dtype == jnp.bfloat16
    assert b16.union_val.dtype == jnp.bfloat16
    assert b16.idx.dtype == jnp.int32      # indices stay exact
    for use_kernels in (False, True):
        z32 = np.asarray(margins_dense(b32, X, use_kernels=use_kernels))
        z16 = np.asarray(margins_dense(b16, X, use_kernels=use_kernels))
        assert z16.dtype == np.float32
        scale = np.abs(z32).max() + 1e-6
        np.testing.assert_allclose(z16, z32, atol=2e-2 * scale)
