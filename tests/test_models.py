"""Per-arch smoke tests (reduced configs) + model-level invariants.

Each of the 10 assigned architectures: instantiate the reduced config, run
one forward + one train step on CPU, assert output shapes and no NaNs
(assignment requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.specs import decode_batch_specs, train_batch_specs
from repro.models import decode as dec
from repro.models.transformer import Model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.steps import make_train_step

MESH = None


def mesh():
    global MESH
    if MESH is None:
        MESH = jax.make_mesh((1, 1), ("data", "model"))
    return MESH


@pytest.fixture(scope="module", params=list(ARCH_IDS))
def arch_setup(request):
    cfg = get_config(request.param, reduced=True)
    m = Model(cfg, mesh())
    params = m.init_params(jax.random.PRNGKey(0))
    return request.param, cfg, m, params


def test_forward_shapes_and_finite(arch_setup):
    arch, cfg, m, params = arch_setup
    batch = train_batch_specs(cfg, batch=2, seq=32, concrete=True)
    logits = m.logits(params, batch)
    S_out = batch["labels"].shape[1]
    assert logits.shape == (2, S_out, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_train_step_reduces_loss(arch_setup):
    arch, cfg, m, params = arch_setup
    opt_cfg = AdamWConfig(lr=1e-2, weight_decay=0.0)
    step_fn, _, _ = make_train_step(m, opt_cfg)
    step = jax.jit(step_fn)
    opt = adamw_init(params, opt_cfg)
    batch = train_batch_specs(cfg, batch=2, seq=32, concrete=True)
    losses = []
    p = params
    for _ in range(5):
        p, opt, metrics = step(p, opt, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], f"{arch}: loss must fall on a fixed batch"


def test_decode_step_shapes(arch_setup):
    arch, cfg, m, params = arch_setup
    cache = dec.init_cache(m, batch=2, max_len=32)
    tok = decode_batch_specs(cfg, 2, concrete=True)["tokens"]
    logits, cache2 = dec.decode_step(m, params, cache, tok)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert int(cache2["length"]) == 1
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_prefill_decode_consistency(arch_setup):
    """Incremental decode after prefill == full forward (cache semantics)."""
    arch, cfg, m, params = arch_setup
    S = 24
    batch = train_batch_specs(cfg, batch=2, seq=S, concrete=True, seed=1)
    full = m.logits(params, batch, train=False)
    cut = 4
    toks = batch["tokens"]
    if cfg.family == "vlm":
        pb = dict(batch)
        pb["tokens"] = toks[:, :toks.shape[1] - cut]
    elif cfg.family == "encdec":
        pb = dict(batch)
        pb["tokens"] = toks[:, :S - cut]
    else:
        pb = {"tokens": toks[:, :S - cut]}
    last, cache = dec.prefill(m, params, pb, max_len=S)
    np.testing.assert_allclose(last[:, 0], full[:, S - cut - 1],
                               rtol=2e-4, atol=2e-4)
    for t in range(cut):
        tok = toks[:, toks.shape[1] - cut + t][:, None]
        lg, cache = dec.decode_step(m, params, cache, tok)
        np.testing.assert_allclose(lg[:, 0], full[:, S - cut + t],
                                   rtol=2e-4, atol=5e-4)


def test_param_count_formula_matches_actual(arch_setup):
    """utils.params analytic count == actual leaf-size sum (pre-padding)."""
    arch, cfg, m, params = arch_setup
    from repro.utils.params import param_count
    actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    # adjust for vocab padding (analytic uses true vocab)
    pad = cfg.padded_vocab - cfg.vocab_size
    pad_params = pad * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    predicted = param_count(cfg) + pad_params
    assert abs(actual - predicted) / actual < 0.02, \
        (arch, actual, predicted)


def test_long_context_families_have_o1_state():
    """ssm/hybrid decode state must not scale with context length."""
    for arch in ("falcon-mamba-7b", "recurrentgemma-2b"):
        cfg = get_config(arch, reduced=True)
        m = Model(cfg, mesh())
        c_small = dec.init_cache(m, batch=1, max_len=64)
        c_large = dec.init_cache(m, batch=1, max_len=4096)
        sz = lambda c: sum(int(np.prod(x.shape)) for x in jax.tree.leaves(c))
        # hybrid has an O(window) attention cache; capped by window
        assert sz(c_large) <= sz(c_small) * 70, arch


def test_window_attention_ring_buffer():
    """Hybrid local attention: decode past the window stays consistent."""
    cfg = get_config("recurrentgemma-2b", reduced=True)  # window 16
    m = Model(cfg, mesh())
    params = m.init_params(jax.random.PRNGKey(3))
    S = 40  # > 2x window
    batch = train_batch_specs(cfg, batch=1, seq=S, concrete=True, seed=5)
    full = m.logits(params, batch, train=False)
    pb = {"tokens": batch["tokens"][:, :S - 8]}
    last, cache = dec.prefill(m, params, pb, max_len=S)
    for t in range(8):
        tok = batch["tokens"][:, S - 8 + t][:, None]
        lg, cache = dec.decode_step(m, params, cache, tok)
        np.testing.assert_allclose(lg[:, 0], full[:, S - 8 + t],
                                   rtol=2e-4, atol=5e-4)
