"""MoE layer unit tests: routing, capacity, dispatch correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as moe_mod
from repro.models import sharding as sh
from repro.models.transformer import Model


def setup(arch="deepseek-moe-16b"):
    cfg = get_config(arch, reduced=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    model = Model(cfg, mesh)
    key = jax.random.PRNGKey(0)
    params = sh.init_params(key, moe_mod.moe_decls(cfg))
    return cfg, mesh, model, params


def manual_moe(cfg, params, x):
    """Dense reference: run every expert on every token, weight by gates."""
    m = cfg.moe
    T = x.shape[0] * x.shape[1]
    xt = x.reshape(T, -1)
    logits = xt.astype(jnp.float32) @ params["router"]
    gates_all = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(gates_all, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(xt, dtype=jnp.float32)
    for e in range(m.n_experts):
        h = jax.nn.silu(xt @ params["w_gate"][e]) * (xt @ params["w_up"][e])
        oe = (h @ params["w_down"][e]).astype(jnp.float32)
        wsel = jnp.sum(jnp.where(ids == e, gates, 0.0), axis=-1)
        out = out + oe * wsel[:, None]
    if m.n_shared:
        sp = params["shared"]
        h = jax.nn.silu(xt @ sp["w_gate"]) * (xt @ sp["w_up"])
        out = out + (h @ sp["w_down"]).astype(jnp.float32)
    return out.reshape(x.shape).astype(x.dtype)


@pytest.mark.parametrize("arch", ["deepseek-moe-16b", "grok-1-314b"])
def test_moe_matches_dense_reference(arch):
    """With ample capacity the sort-based dispatch == dense compute."""
    cfg, mesh, model, params = setup(arch)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    got = moe_mod.apply_moe(cfg, params, x, mesh, model.rules)
    want = manual_moe(cfg, params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 outputs shrink toward zero (dropped)."""
    cfg, mesh, model, params = setup()
    import dataclasses
    tight = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=0.05))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          jnp.float32)
    full = moe_mod.apply_moe(cfg, params, x, mesh, model.rules)
    dropped = moe_mod.apply_moe(tight, params, x, mesh, model.rules)
    # shared experts still contribute; routed part must differ
    assert float(jnp.mean(jnp.abs(full - dropped))) > 1e-5


def test_moe_deterministic():
    cfg, mesh, model, params = setup()
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model),
                          jnp.float32)
    a = moe_mod.apply_moe(cfg, params, x, mesh, model.rules)
    b = moe_mod.apply_moe(cfg, params, x, mesh, model.rules)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_grad_flows_to_router():
    cfg, mesh, model, params = setup()
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, cfg.d_model),
                          jnp.float32)

    def loss(p):
        return jnp.sum(moe_mod.apply_moe(cfg, p, x, mesh, model.rules) ** 2)

    g = jax.grad(loss)(params)
    assert float(jnp.max(jnp.abs(g["router"]))) > 0
    assert float(jnp.max(jnp.abs(g["w_gate"]))) > 0
