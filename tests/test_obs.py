"""Telemetry subsystem (DESIGN.md section 13): registry / trace units,
the record_aux engine contract on both backends, the zero-cost-when-
disabled guarantees, wall-clock bookkeeping, and the SolveHistory edge
paths (divergence guard, lockstep freeze, shrink recheck)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import PCDNConfig, make_problem, scdn, solve
from repro.core.scdn import SCDNConfig
from repro.data import make_classification
from repro.engine import (LocalBackend, ShardedBackend, ShardedPCDNConfig,
                          loop as engine_loop)
from repro.launch.mesh import make_host_mesh


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with both telemetry planes off — the
    module-level gates are process state and must not leak across tests
    (or into the rest of the suite)."""
    obs.disable()
    obs.registry.reset()
    yield
    obs.disable()
    obs.registry.reset()


@pytest.fixture(scope="module")
def data():
    return make_classification(300, 128, sparsity=0.8, corr=0.3, seed=2)


@pytest.fixture(scope="module")
def problem(data):
    X, y, _ = data
    return make_problem(X, y, c=1.0)


# ---------------------------------------------------------------------------
# registry units


def test_registry_disabled_records_nothing():
    obs.inc("x")
    obs.set_gauge("g", 1.0)
    obs.observe("h", 0.5)
    obs.observe_many("h", [1.0, 2.0])
    assert obs.registry.get_registry().empty


def test_registry_counters_gauges_histograms():
    obs.registry.enable()
    obs.inc("c")
    obs.inc("c", 2.0)
    obs.set_gauge("g", 7.0)
    obs.observe_many("q", [1, 1, 1, 2, 3], bounds=obs.Q_BOUNDS)
    snap = obs.registry.get_registry().snapshot()
    assert snap["counters"]["c"] == 3.0
    assert snap["gauges"]["g"] == 7.0
    h = snap["histograms"]["q"]
    assert h["count"] == 5 and h["min"] == 1 and h["max"] == 3


def test_histogram_quantiles_interpolate():
    h = obs.Histogram(bounds=(1.0, 2.0, 4.0, 8.0))
    h.observe_many([0.5] * 50 + [3.0] * 50)
    # half the mass below 1.0, half in (2, 4]: p50 sits at the boundary,
    # p99 inside the (2, 4] bucket
    assert h.quantile(0.5) <= 2.0
    assert 2.0 < h.quantile(0.99) <= 4.0


def test_env_kill_switch(monkeypatch):
    monkeypatch.setenv("REPRO_METRICS", "off")
    assert obs.registry.enable() is False
    obs.inc("x")
    assert obs.registry.get_registry().empty


def test_write_metrics_jsonl(tmp_path):
    obs.registry.enable()
    obs.inc("runs")
    path = tmp_path / "m.jsonl"
    obs.write_metrics(str(path), meta={"cli": "test"})
    obs.write_metrics(str(path), meta={"cli": "test"})
    lines = path.read_text().strip().splitlines()
    assert len(lines) == 2
    rec = json.loads(lines[0])
    assert rec["cli"] == "test"
    assert rec["metrics"]["counters"]["runs"] == 1.0


# ---------------------------------------------------------------------------
# trace units


def test_trace_spans_nest_and_validate():
    tracer = obs.trace.enable(process_name="t")
    with obs.span("outer", "engine"):
        with obs.span("inner", "engine", args={"k": 1}):
            pass
    obs.instant("mark", "engine")
    obs.counter("n_active", 5.0, "engine")
    d = tracer.to_dict()
    n = obs.validate_trace(d)
    assert n >= 4  # 2 spans + instant + counter (+ metadata events)
    names = {e["name"] for e in d["traceEvents"]}
    assert {"outer", "inner", "mark", "n_active"} <= names


def test_trace_disabled_span_is_null():
    assert obs.trace.get_tracer() is None
    with obs.span("x", "engine"):
        pass
    assert obs.trace.get_tracer() is None
    assert obs.trace.save("/nonexistent/never-written.json") is False


def test_validate_trace_rejects_garbage():
    with pytest.raises(ValueError, match="traceEvents"):
        obs.validate_trace({"events": []})
    with pytest.raises(ValueError, match="missing required field"):
        obs.validate_trace({"traceEvents": [{"name": "a", "ph": "X"}]})
    with pytest.raises(ValueError, match="unknown phase"):
        obs.validate_trace({"traceEvents": [
            {"name": "a", "ph": "Z", "ts": 0, "pid": 1, "tid": 1}]})
    # partial overlap on one track: [0, 10] vs [5, 15]
    with pytest.raises(ValueError, match="partially overlaps"):
        obs.validate_trace({"traceEvents": [
            {"name": "a", "ph": "X", "ts": 0, "dur": 10, "pid": 1, "tid": 1},
            {"name": "b", "ph": "X", "ts": 5, "dur": 10, "pid": 1, "tid": 1},
        ]})


def test_validate_trace_file_roundtrip(tmp_path):
    obs.trace.enable(process_name="t")
    with obs.span("s", "main"):
        pass
    path = tmp_path / "t.json"
    assert obs.trace.save(str(path)) is True
    assert obs.validate_trace_file(str(path)) >= 1


# ---------------------------------------------------------------------------
# record_aux: the 10th-output engine contract (DESIGN.md section 13.2)


def test_local_outer_arity_disabled_vs_enabled(problem):
    """Without record_aux the outer returns EXACTLY the 9-tuple contract
    — no extra device outputs ride along for a disabled plane."""
    cfg = PCDNConfig(P=32, max_outer=5, seed=0)
    b_off = LocalBackend(problem, cfg)
    st = b_off.init_state()
    out = b_off.outer(st.w, st.z, st.key, st.active, jnp.asarray(True),
                      jnp.asarray(1.0, st.w.dtype))
    assert len(out) == 9

    import dataclasses
    b_on = LocalBackend(problem,
                        dataclasses.replace(cfg, record_aux=True))
    out = b_on.outer(st.w, st.z, st.key, st.active, jnp.asarray(True),
                     jnp.asarray(1.0, st.w.dtype))
    assert len(out) == 10
    q, alpha = out[9]
    b = problem.n_features // 32 + (problem.n_features % 32 > 0)
    assert q.shape == (b,) and alpha.shape == (b,)


def test_local_aux_lands_in_history_and_matches_ls_steps(problem):
    cfg = PCDNConfig(P=32, max_outer=10, tol_kkt=1e-8, seed=0,
                     record_aux=True)
    res = solve(problem, cfg)
    h = res.history
    assert h.bundle_q is not None and h.bundle_alpha is not None
    K = res.n_outer
    assert h.bundle_q.shape[0] == K == h.bundle_alpha.shape[0]
    # no shrinking: every bundle runs every iteration, no sentinels
    assert np.all(h.bundle_q >= 0)
    assert np.all(np.isfinite(h.bundle_alpha))
    # ls_steps was always the mean over bundles; the aux series must
    # reproduce it exactly
    np.testing.assert_allclose(h.bundle_q.mean(axis=1), h.ls_steps,
                               rtol=1e-6)
    # accepted alphas are Armijo-valid: beta^q in [0, 1] (0 when a
    # bundle exhausts its backtracks near convergence)
    assert np.all(h.bundle_alpha >= 0) and np.all(h.bundle_alpha <= 1.0)


def test_record_aux_does_not_perturb_solution(problem):
    cfg = PCDNConfig(P=32, max_outer=15, tol_kkt=1e-8, seed=0)
    import dataclasses
    r0 = solve(problem, cfg)
    r1 = solve(problem, dataclasses.replace(cfg, record_aux=True))
    assert r1.n_outer == r0.n_outer
    np.testing.assert_array_equal(np.asarray(r0.w), np.asarray(r1.w))
    assert r0.history.bundle_q is None
    assert r1.history.bundle_q is not None


def test_shrink_aux_uses_sentinels(data):
    """Shrinking runs a data-dependent number of bundles per iteration;
    slots past the live count must carry q == -1 / alpha == nan, and the
    two sentinel masks must agree."""
    X, y, _ = data
    prob = make_problem(X, y, c=1.0)      # shrinks to ~16 of 128 active
    cfg = PCDNConfig(P=32, max_outer=40, tol_kkt=1e-6, seed=0,
                     shrink=True, record_aux=True)
    res = solve(prob, cfg)
    h = res.history
    assert h.bundle_q is not None
    ran = h.bundle_q >= 0
    np.testing.assert_array_equal(ran, np.isfinite(h.bundle_alpha))
    assert ran.any(), "some bundles must have run"
    assert (~ran).any(), "shrinking must have idled some bundle slots"
    # rows stay consistent with the rest of the history
    assert h.bundle_q.shape[0] == len(h.n_active) == res.n_outer


def test_fused_kernel_path_reports_aux(problem):
    cfg = PCDNConfig(P=32, max_outer=5, tol_kkt=1e-8, seed=0,
                     use_kernels=True, record_aux=True)
    res = solve(problem, cfg)
    assert res.history.bundle_q is not None
    assert np.all(res.history.bundle_q >= 0)


def test_sharded_1x1_aux(data):
    X, y, _ = data
    mesh = make_host_mesh(1, 1)
    cfg = ShardedPCDNConfig(P_local=32, c=1.0, seed=0, record_aux=True)
    backend = ShardedBackend(X, y, mesh, cfg)
    res = engine_loop.solve(backend, 1.0, max_outer=6, tol_kkt=1e-8)
    h = res.history
    assert h.bundle_q is not None and h.bundle_alpha is not None
    assert h.bundle_q.shape[0] == res.n_outer
    assert np.all(h.bundle_q >= 0)
    np.testing.assert_allclose(h.bundle_q.mean(axis=1), h.ls_steps,
                               rtol=1e-5)


def test_sharded_disabled_arity(data):
    X, y, _ = data
    mesh = make_host_mesh(1, 1)
    cfg = ShardedPCDNConfig(P_local=32, c=1.0, seed=0)
    backend = ShardedBackend(X, y, mesh, cfg)
    st = backend.init_state()
    out = backend.outer(st.w, st.z, st.key, st.active, jnp.asarray(True),
                        jnp.asarray(1.0, backend.dtype))
    assert len(out) == 9
    res = engine_loop.solve(backend, 1.0, max_outer=3, tol_kkt=1e-8)
    assert res.history.bundle_q is None


def test_solver_loop_zero_registry_activity_when_disabled(problem):
    """The acceptance guarantee: an uninstrumented run leaves the
    registry COMPLETELY untouched — no counter, gauge or histogram may
    appear as a side effect of solving."""
    solve(problem, PCDNConfig(P=32, max_outer=5, seed=0))
    assert obs.registry.get_registry().empty
    assert obs.trace.get_tracer() is None


def test_solver_loop_populates_registry_when_enabled(problem):
    obs.enable(metrics=True)
    cfg = PCDNConfig(P=32, max_outer=8, tol_kkt=1e-8, seed=0,
                     record_aux=True)
    res = solve(problem, cfg)
    snap = obs.registry.get_registry().snapshot()
    assert snap["counters"]["solver.outer_iters"] == res.n_outer
    assert snap["histograms"]["solver.iter_seconds"]["count"] == res.n_outer
    hq = snap["histograms"]["solver.bundle_q"]
    assert hq["count"] == int(np.sum(res.history.bundle_q >= 0))
    assert snap["gauges"]["solver.n_active"] == res.history.n_active[-1]


# ---------------------------------------------------------------------------
# wall-clock bookkeeping (the block_until_ready-before-timestamp fix)


def test_wall_clock_monotone_and_sums_to_total(problem):
    import time
    cfg = PCDNConfig(P=32, max_outer=12, tol_kkt=1e-8, seed=0)
    t0 = time.perf_counter()
    res = solve(problem, cfg)
    total = time.perf_counter() - t0
    wt = res.history.wall_time
    assert wt.shape == (res.n_outer,)
    # cumulative seconds: strictly nondecreasing, and the final entry
    # accounts for (almost) the whole solve — device work synced before
    # each timestamp, so no iteration's time leaks past the last row
    assert np.all(np.diff(wt) >= 0)
    assert 0 < wt[-1] <= total
    assert wt[-1] >= 0.5 * total, \
        "per-iteration times must account for the bulk of the solve"


def test_iter_seconds_histogram_consistent_with_wall_time(problem):
    obs.enable(metrics=True)
    cfg = PCDNConfig(P=32, max_outer=10, tol_kkt=1e-8, seed=0)
    res = solve(problem, cfg)
    h = obs.registry.get_registry().snapshot()[
        "histograms"]["solver.iter_seconds"]
    # summed per-iteration device+host time cannot exceed the loop's own
    # cumulative clock (it excludes history bookkeeping between syncs)
    assert h["count"] == res.n_outer
    assert h["sum"] <= res.history.wall_time[-1] * 1.5


# ---------------------------------------------------------------------------
# SolveHistory edge paths


def test_divergence_guard_history_consistent():
    X, y, _ = make_classification(300, 200, sparsity=0.0, corr=0.95,
                                  seed=2, row_normalize=False)
    prob = make_problem(X, y, c=1.0)
    obs.enable(metrics=True)
    res = scdn.solve(prob, SCDNConfig(P_bar=64, max_rounds=30))
    assert res.diverged and not res.converged
    # the aborted loop still records one consistent row per round run
    k = res.n_rounds
    assert len(res.history["objective"]) == k
    assert len(res.history["wall_time"]) == k
    assert obs.registry.get_registry().snapshot()[
        "counters"]["solver.divergence_trips"] == 1.0


def test_divergence_guard_emits_trace_instant():
    X, y, _ = make_classification(300, 200, sparsity=0.0, corr=0.95,
                                  seed=2, row_normalize=False)
    prob = make_problem(X, y, c=1.0)
    tracer = obs.trace.enable(process_name="t")
    scdn.solve(prob, SCDNConfig(P_bar=64, max_rounds=30))
    events = tracer.to_dict()["traceEvents"]
    assert any(e["name"] == "engine.divergence_guard" and e["ph"] == "i"
               for e in events)
    obs.validate_trace(tracer.to_dict())


def test_lockstep_freeze_bitwise():
    """A problem frozen at iteration k must keep its carry bit-identical
    to its value AT k while stragglers keep iterating."""
    rates = jnp.asarray([0.1, 0.5, 0.9], jnp.float32)

    def outer(x, r):
        x = x * r
        kkt = jnp.abs(x)
        return x, x, kkt, jnp.ones_like(x, jnp.int32)

    x0 = jnp.ones((3,), jnp.float32)
    (x,), f, kkt, nnz, n_outer, done = engine_loop.run_lockstep_loop(
        outer, (x0,), (rates,), max_outer=100, tol_kkt=1e-3,
        dtype=jnp.float32)
    assert bool(jnp.all(done))
    x = np.asarray(x)
    n_outer = np.asarray(n_outer)

    def ref(rate, k):
        """Iterative f32 product — the exact arithmetic the loop does."""
        v = np.float32(1.0)
        for _ in range(int(k)):
            v = np.float32(v * np.float32(rate))
        return v

    for i, rate in enumerate((0.1, 0.5, 0.9)):
        # froze exactly at the first k where |x| crosses tol ...
        assert abs(ref(rate, n_outer[i])) <= 1e-3
        assert abs(ref(rate, n_outer[i] - 1)) > 1e-3
        # ... and the frozen value is bit-identical to the value AT k
        assert x[i] == ref(rate, n_outer[i])
    # slower decay -> strictly more iterations (stragglers kept running
    # after the fast problem froze)
    assert n_outer[0] < n_outer[1] < n_outer[2]


def test_shrink_recheck_history_consistent(data):
    """recheck_every > 1: iterations between rechecks still record full
    history rows; n_active may only grow ON a recheck iteration."""
    X, y, _ = data
    prob = make_problem(X, y, c=1.0)
    cfg = PCDNConfig(P=32, max_outer=40, tol_kkt=1e-6, seed=0,
                     shrink=True, recheck_every=5, record_aux=True)
    res = solve(prob, cfg)
    h = res.history
    k = res.n_outer
    for field in ("objective", "kkt", "nnz", "ls_steps", "wall_time",
                  "n_active"):
        assert len(getattr(h, field)) == k, field
    assert h.bundle_q.shape[0] == k
    grow = np.flatnonzero(np.diff(h.n_active) > 0) + 1
    assert all(g % 5 == 0 for g in grow), \
        "un-shrink may only happen on recheck iterations"


# ---------------------------------------------------------------------------
# serving + kernels instrumentation


def test_batcher_latency_quantiles_and_counters():
    from repro.serve.batcher import MicroBatcher
    from repro.serve.predict import ModelBank
    rng = np.random.default_rng(0)
    W = np.zeros((4, 256), np.float32)
    W[:, :8] = rng.standard_normal((4, 8))
    bank = ModelBank.from_dense(W, kind="path")
    obs.enable(metrics=True, trace_=True)
    b = MicroBatcher(bank, buckets=(8, 32), layout="dense")
    X = rng.standard_normal((64, 256)).astype(np.float32)
    for lo, hi in ((0, 5), (5, 37), (37, 64), (0, 30)):
        b.predict(X[lo:hi])
    stats = b.stats()
    assert stats["latency_p50_s"] is not None
    assert stats["latency_p99_s"] >= stats["latency_p50_s"]
    for bucket in stats["buckets"]:
        assert "latency_p50_s" in bucket
    snap = obs.registry.get_registry().snapshot()
    assert snap["counters"]["serve.rows"] == 64 + 30
    assert snap["counters"]["serve.compiles"] == 2  # one per bucket
    obs.validate_trace(obs.trace.get_tracer().to_dict())


def test_batcher_disabled_zero_registry_activity():
    from repro.serve.batcher import MicroBatcher
    from repro.serve.predict import ModelBank
    W = np.zeros((2, 64), np.float32)
    W[:, 0] = 1.0
    b = MicroBatcher(ModelBank.from_dense(W, kind="path"),
                     buckets=(8,), layout="dense")
    b.predict(np.ones((5, 64), np.float32))
    assert obs.registry.get_registry().empty


def test_autotune_lookup_counters(monkeypatch, tmp_path):
    from repro.kernels import autotune
    obs.enable(metrics=True)
    # disabled tuner -> every lookup is a miss ("defaults were used")
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")
    assert autotune.lookup("pcdn_direction", (64, 64), "float32") is None
    snap = obs.registry.get_registry().snapshot()
    assert snap["counters"]["autotune.lookup_misses"] == 1.0
    assert "autotune.lookup_hits" not in snap["counters"]


def test_kernel_launch_counter_eager_only():
    """Eager ops.* dispatch increments kernels.<name>.launches; the same
    op traced under jit must not touch the registry from inside tracing
    (that would be a host callback in the compiled path)."""
    from repro.kernels import ops
    obs.enable(metrics=True)
    XB = jnp.ones((8, 4), jnp.float32)
    u = jnp.full((8,), 0.25, jnp.float32)
    v = jnp.ones((8,), jnp.float32)
    w_B = jnp.zeros((4,), jnp.float32)
    ops.pcdn_direction(XB, u, v, w_B)
    counters = obs.registry.get_registry().counters
    assert counters.get("kernels.pcdn_direction.launches") == 1.0

    @jax.jit
    def traced(XB, u, v, w_B):
        return ops.pcdn_direction(XB, u, v, w_B)[0]
    traced(XB, u, v, w_B)
    counters = obs.registry.get_registry().counters
    assert counters.get("kernels.pcdn_direction.launches") == 1.0, \
        "traced dispatch must not count launches"


# ---------------------------------------------------------------------------
# CLI integration (in-process)


def test_solve_cli_metrics_and_trace(tmp_path):
    from repro.launch import solve as solve_cli
    from repro.data.libsvm import save_libsvm
    X, y, _ = make_classification(120, 60, sparsity=0.5, seed=0)
    ds = tmp_path / "d.svm"
    save_libsvm(str(ds), X, y)
    mpath, tpath, rpath = (str(tmp_path / n) for n in
                           ("m.jsonl", "t.json", "r.json"))
    solve_cli.main(["--dataset", str(ds), "--P", "16", "--max-outer", "10",
                    "--tol", "1e-6", "--c", "5.0",
                    "--metrics-out", mpath, "--trace-out", tpath,
                    "--out", rpath])
    assert obs.validate_trace_file(tpath) > 0
    rec = json.loads(open(mpath).read().strip().splitlines()[-1])
    assert rec["cli"] == "solve"
    assert "solver.bundle_q" in rec["metrics"]["histograms"]
    report = json.load(open(rpath))
    assert "bundle_q" in report["history"]
    assert "bundle_alpha" in report["history"]
    # CLI run disables the planes on exit
    assert not obs.metrics_enabled() and not obs.trace_enabled()


def test_solve_cli_without_flags_records_nothing(tmp_path):
    from repro.launch import solve as solve_cli
    from repro.data.libsvm import save_libsvm
    X, y, _ = make_classification(120, 60, sparsity=0.5, seed=0)
    ds = tmp_path / "d.svm"
    save_libsvm(str(ds), X, y)
    rpath = str(tmp_path / "r.json")
    solve_cli.main(["--dataset", str(ds), "--P", "16", "--max-outer", "5",
                    "--c", "5.0", "--out", rpath])
    assert obs.registry.get_registry().empty
    report = json.load(open(rpath))
    assert "bundle_q" not in report["history"]


def test_obs_validate_cli(tmp_path):
    obs.trace.enable(process_name="t")
    with obs.span("s", "main"):
        pass
    good = tmp_path / "good.json"
    obs.trace.save(str(good))
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"name": "a"}]}))
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-m", "repro.obs.validate",
                        str(good)], capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    r = subprocess.run([sys.executable, "-m", "repro.obs.validate",
                        str(good), str(bad)], capture_output=True,
                       text=True,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       env=env)
    assert r.returncode != 0
