"""Optimizer, schedules, compression, data pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.libsvm import load_libsvm, save_libsvm
from repro.data.synthetic import duplicate_samples, make_classification
from repro.data.tokens import TokenPipeline
from repro.configs import get_config
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import (init_residual, topk_compress_update,
                                     topk_mask)
from repro.optim.schedules import linear_warmup_cosine


# -- AdamW vs a straightforward numpy reference --------------------------------

def np_adamw(w, g, m, v, t, lr, b1, b2, eps, wd):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** t)
    vh = v / (1 - b2 ** t)
    w = w - lr * (mh / (np.sqrt(vh) + eps) + wd * w)
    return w, m, v


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8,
                      weight_decay=0.05, grad_clip=0.0)
    rng = np.random.default_rng(0)
    w = rng.standard_normal(20).astype(np.float32)
    params = {"w": jnp.asarray(w)}
    state = adamw_init(params, cfg)
    m = np.zeros(20, np.float32)
    v = np.zeros(20, np.float32)
    wn = w.copy()
    for t in range(1, 6):
        g = rng.standard_normal(20).astype(np.float32)
        params, state, _ = adamw_update(params, {"w": jnp.asarray(g)},
                                        state, cfg)
        wn, m, v = np_adamw(wn, g, m, v, t, 1e-2, 0.9, 0.99, 1e-8, 0.05)
        np.testing.assert_allclose(np.asarray(params["w"]), wn, rtol=2e-5,
                                   atol=2e-6)


def test_grad_clip_caps_global_norm():
    """The first-moment accumulator sees the clipped gradient: its norm
    must equal (1-b1) * grad_clip when the raw norm exceeds the clip.
    (The Adam *update* itself is scale-invariant on step 1 — the moment is
    the observable.)"""
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0, b1=0.9)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params, cfg)
    g = {"w": jnp.full(4, 100.0)}  # global norm 200
    _, state1, metrics = adamw_update(params, g, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-5)
    mu_norm = float(jnp.linalg.norm(state1.mu["w"]))
    assert mu_norm == pytest.approx((1 - 0.9) * 1.0, rel=1e-4)


def test_schedule_warmup_and_decay():
    f = linear_warmup_cosine(1e-3, warmup_steps=10, total_steps=100)
    assert float(f(jnp.asarray(0))) == 0.0
    assert abs(float(f(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(f(jnp.asarray(95))) < 3e-4


# -- top-k error-feedback compression -------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.01, 0.5))
def test_compression_mass_conservation(seed, frac):
    """sent + residual_new == grads + residual_old (error feedback)."""
    rng = np.random.default_rng(seed)
    g = {"a": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)}
    r = {"a": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)}
    sent, r_new = topk_compress_update(g, r, frac=frac)
    total_in = np.asarray(g["a"]) + np.asarray(r["a"])
    total_out = np.asarray(sent["a"]) + np.asarray(r_new["a"])
    np.testing.assert_allclose(total_in, total_out, rtol=1e-5, atol=1e-6)


def test_compression_sparsity():
    rng = np.random.default_rng(1)
    g = {"a": jnp.asarray(rng.standard_normal(1000), jnp.float32)}
    sent, _ = topk_compress_update(g, init_residual(g), frac=0.01)
    nnz = int(jnp.sum(sent["a"] != 0))
    assert nnz <= 20  # ~1% of 1000 (ties allowed)


# -- data ------------------------------------------------------------------------

def test_libsvm_roundtrip(tmp_path):
    X, y, _ = make_classification(30, 10, sparsity=0.5, seed=0)
    p = str(tmp_path / "d.libsvm")
    save_libsvm(p, X, y)
    X2, y2 = load_libsvm(p, n_features=10)
    np.testing.assert_allclose(X, X2, rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(y, y2)


def test_libsvm_roundtrip_all_layouts(tmp_path):
    """writer -> reader round trip must agree across dense / csr /
    padded_csc — same values, same labels, no densification surprises."""
    X, y, _ = make_classification(40, 12, sparsity=0.6, seed=3)
    p = str(tmp_path / "layouts.libsvm")
    save_libsvm(p, X, y)
    Xd, yd = load_libsvm(p, n_features=12, layout="dense")
    Xc, yc = load_libsvm(p, n_features=12, layout="csr")
    Xp, yp = load_libsvm(p, n_features=12, layout="padded_csc")
    np.testing.assert_allclose(Xd, X, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(Xc.to_dense(), Xd, rtol=0, atol=0)
    from repro.core.design_matrix import PaddedCSCDesign
    dense_from_padded = np.asarray(PaddedCSCDesign(
        col_rows=jnp.asarray(Xp.col_rows), col_vals=jnp.asarray(Xp.col_vals),
        _n_samples=Xp.shape[0]).to_dense())
    np.testing.assert_allclose(dense_from_padded, Xd, rtol=0, atol=0)
    for yy in (yd, yc, yp):
        np.testing.assert_array_equal(yy, y)


def test_libsvm_multiclass_labels(tmp_path):
    """Integer multiclass files load as (X, codes, classes); loading them
    without return_classes raises instead of feeding ids to +-1 solvers."""
    rng = np.random.default_rng(0)
    X = (rng.random((25, 6)) < 0.5) * rng.standard_normal((25, 6))
    labels = rng.choice([2.0, 5.0, 9.0], size=25)
    labels[:3] = [2.0, 5.0, 9.0]          # every class present
    p = str(tmp_path / "mc.libsvm")
    save_libsvm(p, X.astype(np.float32), labels)
    with pytest.raises(ValueError, match="return_classes"):
        load_libsvm(p, n_features=6)
    X2, codes, classes = load_libsvm(p, n_features=6, return_classes=True)
    np.testing.assert_array_equal(classes, [2.0, 5.0, 9.0])
    np.testing.assert_array_equal(classes[codes.astype(np.int64)], labels)
    np.testing.assert_allclose(X2, X, rtol=1e-4, atol=1e-5)
    # binary files keep the historical contract under both signatures
    save_libsvm(p, X.astype(np.float32), np.where(labels > 4, 1.0, -1.0))
    _, yb = load_libsvm(p, n_features=6)
    assert set(np.unique(yb)) <= {-1.0, 1.0}
    _, cb, clb = load_libsvm(p, n_features=6, return_classes=True)
    np.testing.assert_array_equal(clb, [-1.0, 1.0])
    np.testing.assert_array_equal(clb[cb.astype(np.int64)], yb)
    # NON-canonical two-label files ({1,2}-style) must also land on +-1,
    # never on raw codes (a y == 0 class would silently drop out of the
    # +-1 losses)
    two = np.where(labels > 4, 2.0, 1.0)
    save_libsvm(p, X.astype(np.float32), two)
    _, y12 = load_libsvm(p, n_features=6)
    np.testing.assert_array_equal(y12, np.where(two == 2.0, 1.0, -1.0))


def test_duplicate_samples_preserves_correlation():
    X, y, _ = make_classification(50, 8, sparsity=0.2, seed=1)
    X2, y2 = duplicate_samples(X, y, 2.5)
    assert X2.shape[0] == 125
    g1 = X.T @ X / X.shape[0]
    g2 = X2.T @ X2 / X2.shape[0]
    np.testing.assert_allclose(g1, g2, rtol=0.2, atol=0.05)


def test_token_pipeline_deterministic_and_restartable():
    cfg = get_config("qwen2-0.5b", reduced=True)
    p = TokenPipeline(cfg, batch=2, seq=16, seed=5)
    b3a = p.batch_at(3)
    b3b = p.batch_at(3)
    np.testing.assert_array_equal(b3a["tokens"], b3b["tokens"])
    # iterator from a restart offset yields the same stream
    it = p.iterate(start=3)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], b3a["tokens"])


def test_token_pipeline_vlm_masks():
    cfg = get_config("pixtral-12b", reduced=True)
    p = TokenPipeline(cfg, batch=2, seq=16, seed=0)
    b = p.batch_at(0)
    npatch = cfg.vlm.n_patches
    assert b["patches"].shape == (2, npatch, cfg.d_model)
    assert b["loss_mask"].shape[1] == npatch + 16
    assert np.all(b["loss_mask"][:, :npatch] == 0)
