"""Regularization-path engine: grid/c_max analytics, warm-path-vs-cold
equivalence, active-set shrinking, and the vmapped batch solver
(DESIGN.md section 8)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import PCDNConfig, make_problem, solve
from repro.core import bundles as B
from repro.core import pcdn
from repro.data import make_classification
from repro.path import PathConfig, c_grid, run_path, solve_batch

S, N = 300, 192


@pytest.fixture(scope="module")
def data():
    return make_classification(S, N, sparsity=0.9, corr=0.3, seed=0)


@pytest.fixture(scope="module")
def problem(data):
    X, y, _ = data
    return make_problem(X, y, c=1.0)


# -- c_max / grid -------------------------------------------------------------

def test_c_max_threshold(problem, data):
    """w = 0 is the solution at c <= c_max and is not above it."""
    X, y, _ = data
    cmax = problem.c_max()
    below = solve(make_problem(X, y, c=0.95 * cmax),
                  PCDNConfig(P=64, max_outer=30, tol_kkt=1e-5))
    assert int(jnp.sum(below.w != 0)) == 0 and below.converged
    above = solve(make_problem(X, y, c=1.5 * cmax),
                  PCDNConfig(P=64, max_outer=60, tol_kkt=1e-5))
    assert int(jnp.sum(above.w != 0)) > 0


def test_c_grid_geometry():
    cs = c_grid(0.5, n_points=5, span=16.0)
    assert cs.shape == (5,) and cs[0] == pytest.approx(0.5)
    assert cs[-1] == pytest.approx(8.0)
    ratios = cs[1:] / cs[:-1]
    np.testing.assert_allclose(ratios, ratios[0], rtol=1e-12)
    with pytest.raises(ValueError):
        c_grid(0.5, c_final=0.4)
    with pytest.raises(ValueError):
        c_grid(-1.0)


# -- warm path == cold solves -------------------------------------------------

def test_warm_path_matches_cold_solves(problem, data):
    X, y, _ = data
    cfg = PathConfig(solver=PCDNConfig(P=64, max_outer=150, tol_kkt=1e-5),
                     n_points=5, span=20.0)
    res = run_path(problem, cfg)
    assert all(p.converged for p in res.points)
    for i, c in enumerate(res.cs):
        cold = solve(make_problem(X, y, c=float(c)),
                     PCDNConfig(P=64, max_outer=300, tol_kkt=1e-5))
        assert cold.converged
        np.testing.assert_allclose(res.weights[i], np.asarray(cold.w),
                                   atol=2e-3)
        assert res.points[i].objective == pytest.approx(
            cold.objective, rel=1e-5)


def test_path_records_and_best_pick(problem, data):
    X, y, _ = data
    Xv, yv, _ = make_classification(120, N, sparsity=0.9, corr=0.3, seed=5)
    cfg = PathConfig(solver=PCDNConfig(P=64, max_outer=80), n_points=4,
                     span=10.0)
    res = run_path(problem, cfg, val_design=Xv, val_y=yv)
    assert len(res.points) == 4 and res.weights.shape == (4, N)
    assert res.points[0].nnz == 0            # the c_max anchor is all-zero
    accs = [p.val_accuracy for p in res.points]
    assert all(a is not None for a in accs)
    assert res.best_index is not None
    assert res.best.val_accuracy == max(accs)


# -- shrinking ----------------------------------------------------------------

def test_partition_active_covers_exactly_the_active_set():
    key = jax.random.PRNGKey(3)
    active = jnp.asarray(np.random.default_rng(0).random(50) < 0.3)
    idxs, b_active = B.partition_active(key, active, P=8)
    n_act = int(active.sum())
    assert int(b_active) == -(-n_act // 8)
    flat = np.asarray(idxs).ravel()
    real = flat[flat < 50]
    assert sorted(real) == sorted(np.flatnonzero(np.asarray(active)))
    # every real index lives in the leading b_active bundles
    lead = np.asarray(idxs)[:int(b_active)].ravel()
    assert sorted(lead[lead < 50]) == sorted(real)


def test_shrink_matches_noshrink_full_kkt(data):
    X, y, _ = data
    tol = 1e-4
    base = dict(P=64, max_outer=300, tol_kkt=tol)
    r_ns = solve(make_problem(X, y, c=2.0), PCDNConfig(**base))
    r_sh = solve(make_problem(X, y, c=2.0), PCDNConfig(shrink=True, **base))
    assert r_ns.converged and r_sh.converged
    # same full-set KKT stop, same objective at f32 noise
    assert float(r_sh.history.kkt[-1]) <= tol
    assert r_sh.objective == pytest.approx(r_ns.objective, rel=1e-5)
    # shrinking actually shrank something along the way
    assert int(r_sh.history.n_active.min()) < N
    # history exposes the active-set trajectory; non-shrink stays full
    assert int(r_ns.history.n_active.min()) == N


def test_shrink_recheck_unshrinks_violators(data):
    """recheck_every > 1 must still end at the full-set KKT tolerance."""
    X, y, _ = data
    r = solve(make_problem(X, y, c=3.0),
              PCDNConfig(P=64, max_outer=300, tol_kkt=1e-4, shrink=True,
                         recheck_every=5, shrink_tol=0.05))
    assert r.converged
    assert float(r.history.kkt[-1]) <= 1e-4


# -- vmapped batch solving ----------------------------------------------------

def test_batch_matches_looped_solves(problem, data):
    X, y, _ = data
    cs = [0.7, 1.3, 2.6]
    cfg = PCDNConfig(P=64, max_outer=200, tol_kkt=1e-4)
    bres = solve_batch(problem, cfg, cs)
    assert bool(np.all(np.asarray(bres.converged)))
    for i, c in enumerate(cs):
        r = solve(make_problem(X, y, c=c), cfg)
        assert float(bres.objective[i]) == pytest.approx(r.objective,
                                                         rel=1e-4)
        assert float(bres.kkt[i]) <= 1e-4
        assert int(bres.nnz[i]) == int(jnp.sum(r.w != 0))


def test_batch_per_problem_labels_and_seeds(problem, data):
    X, y, _ = data
    rng = np.random.default_rng(7)
    flip = rng.random((2, S)) < 0.2
    ys = np.stack([np.where(flip[i], -y, y) for i in range(2)])
    cfg = PCDNConfig(P=64, max_outer=200, tol_kkt=1e-4)
    bres = solve_batch(problem, cfg, [1.0, 1.0], ys=ys, seeds=[11, 12])
    assert bool(np.all(np.asarray(bres.converged)))
    for i in range(2):
        r = solve(make_problem(X, ys[i], c=1.0),
                  PCDNConfig(P=64, max_outer=200, tol_kkt=1e-4,
                             seed=11 + i))
        assert float(bres.objective[i]) == pytest.approx(r.objective,
                                                         rel=1e-4)


def test_batch_warm_start_freeze_semantics(problem):
    """A problem that starts at its optimum freezes immediately."""
    cfg = PCDNConfig(P=64, max_outer=50, tol_kkt=1e-4)
    r = solve_batch(problem, cfg, [0.8, 1.6])
    again = solve_batch(problem, cfg, [0.8, 1.6],
                        w0=np.asarray(r.w))
    assert bool(np.all(np.asarray(again.converged)))
    assert int(np.max(np.asarray(again.n_outer))) <= 2
    np.testing.assert_allclose(np.asarray(again.objective),
                               np.asarray(r.objective), rtol=1e-6)


# -- CLI drivers --------------------------------------------------------------

def test_path_cli_smoke(tmp_path):
    from repro.launch import path as launch_path
    out = tmp_path / "path.json"
    payload = launch_path.main([
        "--dataset", "a9a", "--scale", "0.02", "--points", "4",
        "--span", "10", "--P", "16", "--max-outer", "60",
        "--tol", "1e-3", "--out", str(out), "--save-weights"])
    assert out.exists() and (tmp_path / "path.json.weights.npy").exists()
    assert len(payload["points"]) == 4
    assert payload["best_c"] is not None


def test_solve_cli_warm_start_roundtrip(tmp_path):
    from repro.launch import solve as launch_solve
    out = tmp_path / "solve.json"
    launch_solve.main(["--dataset", "a9a", "--solver", "pcdn", "--P", "16",
                       "--max-outer", "40", "--out", str(out)])
    # the report's "w" feeds --warm-start; warm resume converges fast
    f2 = launch_solve.main(["--dataset", "a9a", "--solver", "pcdn",
                            "--P", "16", "--max-outer", "40",
                            "--warm-start", str(out)])
    import json
    f1 = json.load(open(out))["objective"]
    assert f2 == pytest.approx(f1, rel=1e-4)
