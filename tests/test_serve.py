"""Serving subsystem (DESIGN.md section 10): artifact format, one-vs-rest
training on the vmapped batch solver, the batched-margin prediction
engine (XLA + Pallas, dense + padded-CSC request layouts), the
microbatching front-end, and the end-to-end fit -> save -> fresh-process
serve demo."""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PCDNConfig, make_problem, solve
from repro.core.design_matrix import PaddedCSCDesign
from repro.data import make_classification, save_libsvm
from repro.data.libsvm import CSRMatrix
from repro.kernels import ops, ref
from repro.serve import artifact as art
from repro.serve import ovr as ovr_mod
from repro.serve.batcher import MicroBatcher, default_buckets
from repro.serve.predict import ModelBank, decide, margins_dense, \
    margins_padded_csc, predict

RNG = np.random.default_rng(7)


def _multiclass_data(s=320, n=96, K=3, seed=0):
    """Planted K-class linear problem with non-contiguous labels."""
    rng = np.random.default_rng(seed)
    X = ((rng.random((s, n)) < 0.25) *
         rng.standard_normal((s, n))).astype(np.float32)
    W = (rng.standard_normal((K, n)) *
         (rng.random((K, n)) < 0.12)).astype(np.float32)
    margins = X @ W.T + 0.3 * rng.standard_normal((s, K))
    labels = np.asarray([3.0, 7.0, 11.0])[np.argmax(margins, axis=1)]
    return X, labels


@pytest.fixture(scope="module")
def ovr_fit():
    X, labels = _multiclass_data()
    cfg = PCDNConfig(P=32, max_outer=150, tol_kkt=1e-3)
    res = ovr_mod.fit_ovr(X, labels, c=2.0, cfg=cfg)
    return X, labels, res


# -- artifacts ----------------------------------------------------------------

def test_artifact_roundtrip_binary(tmp_path):
    w = np.zeros(50)
    w[[3, 17, 40]] = [0.5, -2.0, 1.25]
    m = art.artifact_from_solution(w, "logistic", c=4.0, bias=0.125,
                                   meta={"objective": 1.0})
    assert m.nnz == 3 and m.sparsity() == pytest.approx(0.94)
    p = str(tmp_path / "m.json")
    art.save_model(p, m)
    fam = art.load_model(p)
    assert fam.kind == "binary" and len(fam) == 1
    got = fam.model
    np.testing.assert_array_equal(got.w_indices, [3, 17, 40])
    np.testing.assert_allclose(got.dense_weights(np.float64), w)
    assert got.bias == 0.125 and got.c == 4.0
    assert got.meta["objective"] == 1.0


def test_artifact_validation():
    with pytest.raises(ValueError, match="sorted"):
        art.ModelArtifact(10, np.asarray([4, 2]), np.asarray([1.0, 2.0]),
                          "logistic", 1.0)
    with pytest.raises(ValueError, match="outside"):
        art.ModelArtifact(10, np.asarray([11]), np.asarray([1.0]),
                          "logistic", 1.0)
    with pytest.raises(ValueError, match="share"):
        art.ModelFamily("path", (
            art.artifact_from_solution(np.ones(4), "logistic", 1.0),
            art.artifact_from_solution(np.ones(5), "logistic", 2.0)))
    with pytest.raises(ValueError, match="class label"):
        art.ModelFamily("ovr", (
            art.artifact_from_solution(np.ones(4), "logistic", 1.0),))


def test_load_model_rejects_pre_artifact_report(tmp_path):
    """Old-style --out reports fail load_model with a pointed message but
    keep working as --warm-start inputs (back-compat contract)."""
    from repro.launch import common
    old = {"objective": 1.0, "converged": True, "nnz": 2,
           "n_features": 6, "w_indices": [1, 4], "w_values": [0.5, -0.25],
           "history": {"kkt": [1.0, 0.1]}}
    p = str(tmp_path / "old.json")
    with open(p, "w") as fh:
        json.dump(old, fh)
    with pytest.raises(ValueError, match="pre-artifact"):
        art.load_model(p)
    w0 = common.load_warm_start(p, 6, jnp.float32)
    np.testing.assert_allclose(np.asarray(w0),
                               [0, 0.5, 0, 0, -0.25, 0])


def test_solve_out_is_artifact_and_warm_start(tmp_path):
    """--out now writes the artifact schema while keeping the fields warm
    -start chaining reads; --save-model writes the pure artifact."""
    from repro.launch import common, solve as launch_solve
    out = tmp_path / "report.json"
    model = tmp_path / "model.json"
    launch_solve.main(["--dataset", "a9a", "--P", "16",
                       "--max-outer", "40", "--out", str(out),
                       "--save-model", str(model)])
    payload = json.load(open(out))
    assert payload["schema"] == art.SCHEMA
    assert "history" in payload and "w_indices" in payload
    fam = art.load_model(str(out))          # report doubles as a model
    fam2 = art.load_model(str(model))       # pure artifact
    np.testing.assert_array_equal(fam.model.w_indices,
                                  fam2.model.w_indices)
    assert fam.model.meta["nnz"] == payload["nnz"]
    assert fam.provenance["solver"] == "pcdn"
    w0 = common.load_warm_start(str(out), fam.n_features, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(w0), fam.model.dense_weights(np.float64), atol=1e-7)


def test_path_save_model_family(tmp_path):
    from repro.launch import path as launch_path
    model = tmp_path / "family.json"
    launch_path.main(["--dataset", "a9a", "--scale", "0.02",
                      "--points", "3", "--span", "10", "--P", "16",
                      "--max-outer", "40", "--save-model", str(model)])
    fam = art.load_model(str(model))
    assert fam.kind == "path" and len(fam) == 3
    assert list(fam.cs) == sorted(fam.cs)       # sweep order, ascending c
    assert fam.models[0].nnz == 0               # the c_max anchor
    bank = ModelBank.from_family(fam)
    z = predict(bank, np.zeros((2, fam.n_features), np.float32))
    assert np.asarray(z).shape == (2, 3)


# -- one-vs-rest --------------------------------------------------------------

def test_ovr_fit_accuracy_and_diagnostics(ovr_fit):
    X, labels, res = ovr_fit
    assert list(res.classes) == [3.0, 7.0, 11.0]
    assert bool(np.all(np.asarray(res.batch.converged)))
    assert res.train_accuracy >= 0.85
    assert res.weights.shape == (3, X.shape[1])
    # every subproblem is genuinely sparse (l1 did its job)
    assert int(np.count_nonzero(res.weights)) < 3 * X.shape[1]


def test_ovr_canonicalizes_unsorted_vocabulary():
    """A caller-supplied unsorted `classes` is remapped to the sorted
    vocabulary every other layer assumes (libsvm codes, artifact order,
    launch.predict's code comparison), preserving label semantics; a
    hand-built unsorted ovr family is rejected outright."""
    rng = np.random.default_rng(5)
    X = ((rng.random((150, 30)) < 0.3) *
         rng.standard_normal((150, 30))).astype(np.float32)
    true = rng.integers(0, 3, 150)
    classes_unsorted = np.asarray([7.0, 3.0, 5.0])
    cfg = PCDNConfig(P=16, max_outer=60, tol_kkt=1e-2)
    res = ovr_mod.fit_ovr(X, true, c=1.5, cfg=cfg,
                          classes=classes_unsorted)
    assert list(res.classes) == [3.0, 5.0, 7.0]
    pred = res.classes[np.argmax(np.asarray(res.batch.z), axis=0)]
    acc = float(np.mean(pred == classes_unsorted[true]))
    assert acc == pytest.approx(res.train_accuracy, abs=1e-12)
    ovr_mod.ovr_family(res, "logistic")   # passes the sortedness guard
    with pytest.raises(ValueError, match="ascending label order"):
        art.ModelFamily("ovr", tuple(
            art.artifact_from_solution(np.ones(4), "logistic", 1.0,
                                       label=lb) for lb in (7.0, 3.0)))


def test_ovr_matches_solo_binary_solve(ovr_fit):
    """Subproblem k of the vmapped OVR fit == a solo pcdn.solve on the
    same +-1 relabeling (the solve_batch equivalence, OVR-shaped)."""
    X, labels, res = ovr_fit
    cfg = PCDNConfig(P=32, max_outer=150, tol_kkt=1e-3)
    k = 1
    yk = np.where(labels == res.classes[k], 1.0, -1.0).astype(np.float32)
    solo = solve(make_problem(X, yk, c=2.0), cfg)
    assert float(res.batch.objective[k]) == pytest.approx(solo.objective,
                                                          rel=1e-4)


def test_ovr_family_serves(ovr_fit, tmp_path):
    X, labels, res = ovr_fit
    fam = ovr_mod.ovr_family(res, "logistic",
                             provenance=art.solver_provenance(P=32))
    p = str(tmp_path / "ovr.json")
    art.save_model(p, fam)
    fam2 = art.load_model(p)
    np.testing.assert_array_equal(fam2.classes, res.classes)
    bank = ModelBank.from_family(fam2)
    preds = decide(bank, predict(bank, X))
    assert float(np.mean(preds == labels)) == \
        pytest.approx(res.train_accuracy, abs=1e-9)


# -- prediction engine --------------------------------------------------------

def _random_bank(K, n, a_lo, a_hi, seed=0, with_empty=False):
    rng = np.random.default_rng(seed)
    W = np.zeros((K, n), np.float32)
    for k in range(int(with_empty), K):   # model 0 stays all-zero if asked
        a = rng.integers(a_lo, a_hi + 1)
        W[k, rng.choice(n, a, replace=False)] = rng.standard_normal(a)
    return W, ModelBank.from_dense(W, kind="path")


@pytest.mark.parametrize("B,n,K", [(17, 40, 1), (64, 96, 5), (130, 33, 4)])
def test_margins_all_four_paths_match_dense_matmul(B, n, K):
    rng = np.random.default_rng(B + n)
    W, bank = _random_bank(K, n, 1, max(2, n // 8), seed=n,
                           with_empty=(K > 1))
    X = rng.standard_normal((B, n)).astype(np.float32)
    want = X @ W.T
    got = {
        "xla_dense": margins_dense(bank, X),
        "pallas_dense": margins_dense(bank, X, use_kernels=True),
        "xla_csc": margins_padded_csc(bank, PaddedCSCDesign.from_dense(X)),
        "pallas_csc": margins_padded_csc(
            bank, PaddedCSCDesign.from_dense(X), use_kernels=True),
    }
    for name, z in got.items():
        np.testing.assert_allclose(np.asarray(z), want, rtol=1e-4,
                                   atol=1e-4, err_msg=name)


def test_margin_kernels_match_refs_with_padding():
    """Raw kernel vs jnp oracle with sentinel-padded model rows."""
    rng = np.random.default_rng(3)
    B, n, K, A = 24, 30, 3, 6
    X = jnp.asarray(rng.standard_normal((B, n)), jnp.float32)
    idx = np.full((K, A), n, np.int32)
    val = np.zeros((K, A), np.float32)
    for k in range(K):
        a = rng.integers(1, A + 1)
        idx[k, :a] = np.sort(rng.choice(n, a, replace=False))
        val[k, :a] = rng.standard_normal(a)
    idx, val = jnp.asarray(idx), jnp.asarray(val)
    np.testing.assert_allclose(
        np.asarray(ops.serve_margins_dense(X, idx, val)),
        np.asarray(ref.serve_margins_dense_ref(X, idx, val)),
        rtol=1e-5, atol=1e-5)
    d = PaddedCSCDesign.from_dense(np.asarray(X))
    np.testing.assert_allclose(
        np.asarray(ops.serve_margins_csc(d.col_rows, d.col_vals, idx, val,
                                         n_requests=B)),
        np.asarray(ref.serve_margins_csc_ref(d.col_rows, d.col_vals, idx,
                                             val, B)),
        rtol=1e-5, atol=1e-5)


def test_bank_bias_and_decide():
    W = np.zeros((2, 8), np.float32)
    W[0, 1] = 1.0
    W[1, 2] = 1.0
    bank = ModelBank.from_dense(W, bias=[0.0, 10.0], kind="ovr",
                                classes=np.asarray([5.0, 6.0]))
    X = np.zeros((3, 8), np.float32)
    z = np.asarray(predict(bank, X))
    np.testing.assert_allclose(z, [[0.0, 10.0]] * 3)
    np.testing.assert_array_equal(decide(bank, z), [6.0, 6.0, 6.0])
    wb = ModelBank.from_dense(W[0], kind="binary")
    assert decide(wb, np.asarray([[0.5], [-0.5], [0.0]])).tolist() == \
        [1.0, -1.0, 1.0]


# -- microbatcher -------------------------------------------------------------

def test_default_buckets():
    assert default_buckets(64) == (1, 2, 4, 8, 16, 32, 64)
    assert default_buckets(48) == (1, 2, 4, 8, 16, 32, 48)
    assert default_buckets(1) == (1,)


def test_batcher_dense_pads_and_accounts():
    W, bank = _random_bank(3, 24, 2, 6, seed=1)
    X = RNG.standard_normal((41, 24)).astype(np.float32)
    b = MicroBatcher(bank, buckets=(4, 16), layout="dense")
    z = b.predict(X)
    np.testing.assert_allclose(z, np.asarray(margins_dense(bank, X)),
                               rtol=1e-5, atol=1e-6)
    st = b.stats()
    assert st["total_rows"] == 41
    by = {s["bucket"]: s for s in st["buckets"]}
    # 41 = 2 full chunks of 16 + tail 9 -> bucket 16 (padded by 7)
    assert by[16]["calls"] == 3 and by[16]["pad_rows"] == 7
    assert 4 not in by
    # steady state: repeated traffic adds calls, not compiles
    b.predict(X[:16]); b.predict(X[:16])
    st2 = b.stats()
    assert st2["compiles"] == 1
    b16 = {s["bucket"]: s for s in st2["buckets"]}[16]
    assert b16["calls"] == 5
    # throughput counts REAL served rows only, not padding: 73 total
    # real rows minus the 16 of the warmup call over the busy seconds
    assert b16["warmup_rows"] == 16
    if b16["busy_seconds"] > 0:
        assert b16["rows_per_s"] == pytest.approx(
            (b16["rows"] - 16) / b16["busy_seconds"])


def test_batcher_csc_matches_dense_layout():
    W, bank = _random_bank(4, 32, 3, 8, seed=2)
    Xd = ((RNG.random((23, 32)) < 0.3) *
          RNG.standard_normal((23, 32))).astype(np.float32)
    csr = CSRMatrix.from_dense(Xd)
    b = MicroBatcher(bank, buckets=(8, 16), layout="padded_csc",
                     k_max=csr.max_col_nnz())
    z = b.predict(csr)
    np.testing.assert_allclose(z, np.asarray(margins_dense(bank, Xd)),
                               rtol=1e-4, atol=1e-5)
    assert b.stats()["total_rows"] == 23


def test_batcher_guards():
    _, bank = _random_bank(2, 16, 2, 4)
    with pytest.raises(ValueError, match="k_max"):
        MicroBatcher(bank, layout="padded_csc")
    b = MicroBatcher(bank, buckets=(4,), layout="dense")
    with pytest.raises(ValueError, match="features"):
        b.predict(np.zeros((2, 9), np.float32))


def test_two_class_ovr_serves_against_its_own_file(tmp_path):
    """K=2 OVR with raw labels {3, 7}: the libsvm loader normalizes any
    two-label file to a +-1 vocabulary, so the CLI must compare on class
    CODES (sorted-vocabulary order), not raw label values — otherwise
    accuracy is 0.0 by construction."""
    from repro.launch import predict as launch_predict
    rng = np.random.default_rng(11)
    s, n = 200, 40
    X = ((rng.random((s, n)) < 0.3) *
         rng.standard_normal((s, n))).astype(np.float32)
    w = (rng.standard_normal(n) * (rng.random(n) < 0.2)).astype(np.float32)
    labels = np.where(X @ w > 0, 7.0, 3.0)
    res = ovr_mod.fit_ovr(X, labels, c=2.0,
                          cfg=PCDNConfig(P=16, max_outer=80, tol_kkt=1e-2))
    model_path = str(tmp_path / "two.json")
    art.save_model(model_path, ovr_mod.ovr_family(res, "logistic"))
    data_path = str(tmp_path / "two.libsvm")
    save_libsvm(data_path, X, labels)
    payload = launch_predict.main(["--model", model_path,
                                   "--dataset", data_path,
                                   "--max-batch", "64"])
    assert payload["accuracy"] == pytest.approx(res.train_accuracy,
                                                abs=0.02)
    assert payload["accuracy"] > 0.5


def test_bench_serve_reports_sparse_gather_headline():
    """The committed BENCH_serve.json must report the acceptance number:
    >= 2x throughput for the sparse-gather scorer over dense margins at
    >= 0.99 weight sparsity (full-run figures; smoke runs in CI only
    overwrite the file AFTER the test stage)."""
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_serve.json")
    if not os.path.exists(path):
        pytest.skip("no BENCH_serve.json checked out")
    payload = json.load(open(path))
    if payload.get("smoke"):
        pytest.skip("local --smoke run overwrote the committed full-run "
                    "figures; the acceptance number is pinned on full runs")
    assert payload["speedup_at_ge_099"] >= 2.0
    assert payload["headline_sparsity"] >= 0.99
    at99 = [r for r in payload["scorer"] if r["sparsity"] >= 0.99]
    assert at99 and all(r["max_abs_err"] < 1e-3 for r in at99)


# -- end-to-end: fit OVR -> save family -> serve from a fresh process ---------

def test_end_to_end_multiclass_serving(ovr_fit, tmp_path):
    """The acceptance demo: multiclass OVR fit on the batch solver, saved
    as an artifact family, reloaded in a FRESH python process, served
    through the microbatched engine with Pallas-kernel margins checked
    against the reference scorer, predictions matching in-process ones."""
    X, labels, res = ovr_fit
    fam = ovr_mod.ovr_family(res, "logistic")
    model_path = str(tmp_path / "ovr_model.json")
    art.save_model(model_path, fam)

    data_path = str(tmp_path / "requests.libsvm")
    save_libsvm(data_path, X, labels)

    # in-process reference predictions
    bank = ModelBank.from_family(fam)
    want_pred = decide(bank, predict(bank, X))
    want_acc = float(np.mean(want_pred == labels))

    for layout in ("dense", "padded_csc"):
        out = str(tmp_path / f"preds_{layout}.json")
        env = dict(os.environ)
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        env.setdefault("JAX_PLATFORMS", "cpu")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.predict",
             "--model", model_path, "--dataset", data_path,
             "--layout", layout, "--use-kernels",
             "--buckets", "32,128", "--out", out],
            env=env, capture_output=True, text=True, timeout=600)
        assert proc.returncode == 0, proc.stderr + proc.stdout
        payload = json.load(open(out))
        assert payload["accuracy"] == pytest.approx(want_acc, abs=1e-9)
        np.testing.assert_array_equal(np.asarray(payload["predictions"]),
                                      want_pred)
        assert payload["stats"]["compiles"] <= 2   # one per bucket shape
        assert "kernel-vs-reference" in proc.stdout
