"""Continuous-batching serving loop (DESIGN.md section 14): deadline vs
full flush policy, best-c selection over path families, measured-crossover
scorer routing, capacity-padded banks, zero-downtime hot-swap (zero
recompiles, torn-read-free responses), admission control, the Poisson
driver, and the committed BENCH_serve2.json acceptance guard."""
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.serve import artifact as art
from repro.serve.loop import (ServeLoop, ServeOverload, SwapCapacityError,
                              _bank_capacity, drive_poisson)
from repro.serve.predict import (ModelBank, margins_dense, pick_route,
                                 scorer_cache_sizes, set_route_crossover)

RNG = np.random.default_rng(13)


def _binary_family(n, nnz, seed=0, scale=1.0, meta=None):
    rng = np.random.default_rng(seed)
    w = np.zeros(n, np.float64)
    w[rng.choice(n, nnz, replace=False)] = scale * rng.standard_normal(nnz)
    m = art.artifact_from_solution(w, "logistic", c=1.0,
                                   bias=float(rng.standard_normal()),
                                   meta=meta or {})
    return art.ModelFamily(kind="binary", models=(m,))


def _path_family(n, metas, seed=0):
    """kind="path" family with one member per meta dict; member i has
    i+1 nonzeros (strictly growing support, like a real c-sweep)."""
    rng = np.random.default_rng(seed)
    sup = rng.choice(n, len(metas), replace=False)
    models = []
    for i, meta in enumerate(metas):
        w = np.zeros(n, np.float64)
        w[sup[:i + 1]] = rng.standard_normal(i + 1)
        models.append(art.artifact_from_solution(
            w, "logistic", c=float(2.0 ** i), meta=dict(meta, nnz=i + 1)))
    return art.ModelFamily(kind="path", models=tuple(models))


# -- pick_best_c --------------------------------------------------------------

def test_pick_best_c_metric_ties_and_errors():
    fam = _path_family(64, [{"val_accuracy": 0.70},
                            {"val_accuracy": 0.90},
                            {"val_accuracy": 0.90},
                            {"val_accuracy": 0.85}])
    # max metric, tie (members 1 and 2 at 0.90) -> fewer nonzeros wins
    i, best = art.pick_best_c(fam, metric="val_accuracy")
    assert i == 1 and best.nnz == 2
    # metric="nnz" -> sparsest member
    i, best = art.pick_best_c(fam, metric="nnz")
    assert i == 0 and best.nnz == 1
    # a family whose members never recorded the metric has nothing to
    # select on — the error points at --val-frac
    bare = _path_family(64, [{}, {}])
    with pytest.raises(ValueError, match="val-frac"):
        art.pick_best_c(bare)
    with pytest.raises(ValueError, match="path"):
        art.pick_best_c(_binary_family(64, 3))


def test_pick_best_c_equal_nnz_tie_prefers_earlier_grid_point():
    rng = np.random.default_rng(4)
    sup = rng.choice(32, 2, replace=False)
    models = []
    for i in range(2):                       # same metric, same nnz
        w = np.zeros(32, np.float64)
        w[sup] = rng.standard_normal(2)
        models.append(art.artifact_from_solution(
            w, "logistic", c=float(i + 1), meta={"val_accuracy": 0.8}))
    fam = art.ModelFamily(kind="path", models=tuple(models))
    i, _ = art.pick_best_c(fam)
    assert i == 0                            # smaller c, stronger l1


# -- capacity-padded banks ----------------------------------------------------

def test_capacity_bank_pads_and_scores_identically():
    rng = np.random.default_rng(2)
    W = (rng.standard_normal((3, 48)) * (rng.random((3, 48)) < 0.2)) \
        .astype(np.float32)
    tight = ModelBank.from_dense(W, kind="path")
    wide = ModelBank.from_dense(W, kind="path", a_cap=2 * tight.a_max,
                                u_cap=2 * int(tight.union_idx.shape[0]))
    assert wide.a_max == 2 * tight.a_max
    assert int(wide.union_idx.shape[0]) == 2 * int(tight.union_idx.shape[0])
    X = rng.standard_normal((9, 48)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(margins_dense(wide, X)),
                               np.asarray(margins_dense(tight, X)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(margins_dense(wide, X, route="dense")),
        np.asarray(margins_dense(tight, X)), rtol=1e-4, atol=1e-4)


def test_capacity_overflow_raises():
    rng = np.random.default_rng(3)
    W = rng.standard_normal((2, 32)).astype(np.float32)   # fully dense rows
    with pytest.raises(ValueError, match="capacity"):
        ModelBank.from_dense(W, a_cap=4)
    with pytest.raises(ValueError, match="capacity"):
        ModelBank.from_dense(W, u_cap=8)


def test_bank_capacity_headroom():
    fam = _path_family(64, [{"val_accuracy": 0.7}, {"val_accuracy": 0.8},
                            {"val_accuracy": 0.9}])
    a_cap, u_cap = _bank_capacity(fam, 2.0)
    assert a_cap == 6 and u_cap == 6         # max nnz 3, union 3, x2


# -- measured-crossover routing -----------------------------------------------

def test_pick_route_uses_crossover_table():
    try:
        set_route_crossover([
            {"sparsity": 0.9, "min_batch_sparse": None},
            {"sparsity": 0.99, "min_batch_sparse": 256},
            {"sparsity": 0.999, "min_batch_sparse": 64}])
        assert pick_route(0.95, 10_000) == "dense"   # None: dense always
        assert pick_route(0.995, 255) == "dense"
        assert pick_route(0.995, 256) == "sparse"
        assert pick_route(0.9995, 64) == "sparse"
        assert pick_route(0.9995, 63) == "dense"
        assert pick_route(0.5, 4096) == "dense"      # below the table
    finally:
        set_route_crossover(None)                    # restore measured file


def test_margins_route_equivalence_and_validation():
    rng = np.random.default_rng(5)
    W = (rng.standard_normal((4, 40)) * (rng.random((4, 40)) < 0.15)) \
        .astype(np.float32)
    bank = ModelBank.from_dense(W, kind="path")
    X = rng.standard_normal((13, 40)).astype(np.float32)
    want = np.asarray(margins_dense(bank, X))        # sparse route
    np.testing.assert_allclose(
        np.asarray(margins_dense(bank, X, route="dense")), want,
        rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(margins_dense(bank, X, route="auto")), want,
        rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError, match="route"):
        margins_dense(bank, X, route="csc")


# -- the loop: flush policy ---------------------------------------------------

def test_loop_full_and_deadline_and_drain_flushes():
    fam = _binary_family(32, 5, seed=7)
    with ServeLoop(fam, buckets=(4,), default_budget_s=10.0) as loop:
        X = RNG.standard_normal((4, 32)).astype(np.float32)
        # a full bucket flushes immediately regardless of the far deadline
        futs = loop.submit_many(X)
        res = [f.result(timeout=30) for f in futs]
        assert all(r.flush_reason == "full" and r.bucket == 4 for r in res)
        want = np.asarray(margins_dense(loop.bank(), X))
        np.testing.assert_allclose(
            np.stack([r.margins for r in res]), want, rtol=1e-5, atol=1e-5)
        # a lone request cannot fill the bucket: its own deadline flushes it
        r1 = loop.submit(X[0], budget_s=0.05).result(timeout=30)
        assert r1.flush_reason == "deadline"
        assert r1.latency_s <= 5.0           # bounded, not stranded
        # requests pending at stop() flush as "drain"
        f_last = loop.submit(X[1], budget_s=10.0)
    r_last = f_last.result(timeout=30)
    assert r_last.flush_reason == "drain"
    st = loop.stats()["models"]["default"]
    assert st["flushes"]["full"] >= 1
    assert st["flushes"]["deadline"] >= 1
    assert st["flushes"]["drain"] >= 1
    assert loop.stats()["responses"] == 6


def test_loop_multi_model_routing_and_validation():
    fams = {"a": _binary_family(24, 4, seed=1),
            "b": _binary_family(40, 6, seed=2)}    # heterogeneous widths
    with ServeLoop(fams, buckets=(1, 2), default_budget_s=0.05) as loop:
        assert loop.models() == ("a", "b")
        xa = RNG.standard_normal(24).astype(np.float32)
        xb = RNG.standard_normal(40).astype(np.float32)
        ra = loop.submit(xa, model="a").result(timeout=30)
        rb = loop.submit(xb, model="b").result(timeout=30)
        assert ra.model == "a" and rb.model == "b"
        np.testing.assert_allclose(
            ra.margins, np.asarray(margins_dense(loop.bank("a"),
                                                 xa[None, :]))[0],
            rtol=1e-5, atol=1e-5)
        with pytest.raises(ValueError, match="pick one"):
            loop.submit(xa)                  # ambiguous without model=
        with pytest.raises(KeyError, match="unknown model"):
            loop.submit(xa, model="zzz")
        with pytest.raises(ValueError, match="features"):
            loop.submit(xa, model="b")       # 24 features into a 40-wide slot


def test_loop_overload_admission_control():
    fam = _binary_family(16, 3, seed=9)
    with ServeLoop(fam, buckets=(8,), default_budget_s=30.0,
                   max_queue=4) as loop:
        X = RNG.standard_normal((8, 16)).astype(np.float32)
        futs = [loop.submit(x) for x in X[:4]]   # fills the queue; the far
        with pytest.raises(ServeOverload):       # deadline parks the flush
            loop.submit(X[4])
        assert loop.stats()["rejects"] == 1
    assert all(f.result(timeout=30).flush_reason == "drain" for f in futs)


# -- warm start + hot swap ----------------------------------------------------

def test_loop_steady_traffic_and_swap_never_recompile():
    """The warm-start regression: every (slot, bucket) program is compiled
    at construction, so steady traffic — including ACROSS a hot-swap —
    leaves every jit cache exactly where warmup put it."""
    fam = _binary_family(48, 6, seed=11)
    with ServeLoop(fam, buckets=(1, 2, 4), default_budget_s=0.05) as loop:
        assert loop.stats()["compiles"] >= 1     # warmup did compile
        sizes0 = scorer_cache_sizes()
        X = RNG.standard_normal((16, 48)).astype(np.float32)
        for f in loop.submit_many(X[:5]):
            f.result(timeout=30)
        assert scorer_cache_sizes() == sizes0    # traffic: no compiles
        ticket = loop.swap(model=_binary_family(48, 9, seed=12))
        assert ticket.installed.wait(timeout=30)
        assert ticket.version == 2
        for f in loop.submit_many(X[5:]):
            f.result(timeout=30)
        assert scorer_cache_sizes() == sizes0    # swap + traffic: still none
        st = loop.stats()["models"]["default"]
        assert st["version"] == 2 and st["installs"] == 1


def test_hot_swap_responses_match_version_at_flush_time():
    """Torn-read correctness: under concurrent submit/swap traffic, every
    response's margins equal a from-scratch score with the bank version
    that was installed at its batch's flush time."""
    n = 40
    fams = [_binary_family(n, 5, seed=21 + v, scale=1.0 + v)
            for v in range(3)]
    caps = _bank_capacity(fams[0], 2.0)
    ref_banks = {v + 1: ModelBank.from_family(f, a_cap=caps[0],
                                              u_cap=caps[1])
                 for v, f in enumerate(fams)}
    X = RNG.standard_normal((60, n)).astype(np.float32)
    results, errors = [], []

    with ServeLoop(fams[0], buckets=(1, 2, 4),
                   default_budget_s=0.01) as loop:
        def swapper():
            for f in fams[1:]:
                time.sleep(0.02)
                loop.swap(model=f).installed.wait(timeout=30)
        th = threading.Thread(target=swapper)
        th.start()
        for x in X:
            try:
                results.append(loop.submit(x).result(timeout=30))
            except Exception as e:            # pragma: no cover
                errors.append(e)
        th.join()

    assert not errors
    assert len(results) == len(X)
    seen = sorted({r.version for r in results})
    assert seen[0] == 1 and seen[-1] == 3     # traffic spanned all installs
    for i, r in enumerate(results):
        want = np.asarray(margins_dense(ref_banks[r.version],
                                        X[i][None, :]))[0]
        np.testing.assert_allclose(r.margins, want, rtol=1e-5, atol=1e-5,
                                   err_msg=f"request {i} version {r.version}")


def test_swap_from_path_family_picks_best_c():
    fam0 = _binary_family(64, 4, seed=31)
    path = _path_family(64, [{"val_accuracy": 0.6}, {"val_accuracy": 0.95},
                             {"val_accuracy": 0.8}], seed=32)
    _, best = art.pick_best_c(path)
    with ServeLoop(fam0, buckets=(1,), default_budget_s=0.02) as loop:
        loop.swap(model=path).installed.wait(timeout=30)
        x = RNG.standard_normal(64).astype(np.float32)
        r = loop.submit(x).result(timeout=30)
        want = float(x @ best.dense_weights(np.float64) + best.bias)
        assert r.version == 2
        np.testing.assert_allclose(r.margins, [want], rtol=1e-4, atol=1e-4)


def test_swap_capacity_error():
    fam0 = _binary_family(64, 4, seed=41)
    too_big = _binary_family(64, 30, seed=42)   # > 2x headroom of nnz=4
    with ServeLoop(fam0, buckets=(1,), capacity_factor=2.0) as loop:
        with pytest.raises(SwapCapacityError):
            loop.swap(model=too_big)
        with pytest.raises(SwapCapacityError, match="do not match"):
            loop.swap(model=ModelBank.from_dense(
                np.ones((2, 64), np.float32)))  # K=2 into a K=1 slot
        assert loop.version() == 1              # slot untouched


# -- poisson driver -----------------------------------------------------------

def test_drive_poisson_accounts_offered_load():
    fam = _binary_family(32, 4, seed=51)
    X = RNG.standard_normal((16, 32)).astype(np.float32)
    with ServeLoop(fam, buckets=(1, 2, 4, 8), default_budget_s=0.25,
                   max_queue=64) as loop:
        out = drive_poisson(loop, X, rate_rps=300.0, n_requests=60,
                            seed=3, timeout_s=60.0)
    assert out["responses"] + out["rejects"] == out["n_requests"] == 60
    assert out["offered_rps"] > 0
    assert len(out["results"]) == out["responses"]
    if out["responses"]:
        assert out["p99_s"] >= out["p50_s"] > 0
    with pytest.raises(ValueError, match="rate_rps"):
        drive_poisson(None, X, rate_rps=0.0, n_requests=1)


# -- committed benchmark guards -----------------------------------------------

def _bench(name):
    path = os.path.join(os.path.dirname(__file__), os.pardir, name)
    if not os.path.exists(path):
        pytest.skip(f"no {name} checked out")
    payload = json.load(open(path))
    if payload.get("smoke"):
        pytest.skip("local --smoke run overwrote the committed full-run "
                    "figures; the acceptance number is pinned on full runs")
    return payload


def test_bench_serve2_headline_loop_vs_sync():
    """The committed BENCH_serve2.json must report the acceptance number:
    the continuous-batching loop sustains >= 2x the synchronous
    per-request baseline's rows/s at the same p99 SLO."""
    payload = _bench("BENCH_serve2.json")
    assert payload["headline_speedup"] >= 2.0
    assert payload["loop"]["max_sustained_rps"] is not None
    assert payload["loop"]["max_sustained_rps"] >= \
        2.0 * payload["sync"]["max_sustained_rps"]


def test_bench_serve2_hot_swap_is_invisible():
    """Hot-swap under load: zero recompiles and zero SLO violations
    attributable to the swap windows."""
    hs = _bench("BENCH_serve2.json")["hot_swap"]
    assert hs["n_swaps"] >= 1
    assert hs["recompiles"] == 0
    assert hs["swap_window_violations"] == 0
    assert hs["rejects"] == 0
    # every install landed and traffic saw each version
    assert len(set(hs["response_versions"])) == hs["n_swaps"] + 1


def test_bench_serve_commits_route_crossover_table():
    """BENCH_serve.json carries the measured dense-vs-sparse crossover
    that pick_route / --route auto consult."""
    table = _bench("BENCH_serve.json")["route_crossover"]
    assert [r["sparsity"] for r in table] == sorted(
        r["sparsity"] for r in table)
    for row in table:
        assert row["min_batch_sparse"] is None or row["min_batch_sparse"] >= 1
    # at extreme sparsity the union-gather route must win somewhere
    assert any(r["sparsity"] >= 0.999 and r["min_batch_sparse"] is not None
               for r in table)


# -- batch-failure resilience (DESIGN.md section 16.6) -------------------------

def test_batch_retry_recovers_transient_failure(monkeypatch):
    """One transient scorer failure is retried in place: the caller's
    future resolves normally and only the retry counter moves."""
    import repro.serve.loop as loop_mod
    fam = _binary_family(32, 5, seed=3)
    real = margins_dense
    boom = {"left": 1}

    def flaky(bank, X, **kw):
        if boom["left"]:
            boom["left"] -= 1
            raise RuntimeError("transient device loss")
        return real(bank, X, **kw)

    with ServeLoop(fam, buckets=(1,), default_budget_s=0.05,
                   batch_retries=2) as loop:
        x = RNG.standard_normal(32).astype(np.float32)
        monkeypatch.setattr(loop_mod, "margins_dense", flaky)
        r = loop.submit(x).result(timeout=30)
        np.testing.assert_allclose(
            r.margins, np.asarray(real(loop.bank(), x[None, :]))[0],
            rtol=1e-5, atol=1e-5)
        st = loop.stats()["models"]["default"]
        assert st["retries"] == 1
        assert st["failed_batches"] == 0
        assert st["consecutive_failures"] == 0
        assert not st["quarantined"]
        assert loop.stats()["errors"] == 0


def test_quarantine_after_consecutive_failures_and_swap_clears(monkeypatch):
    """Retries exhausted N batches in a row -> the slot quarantines
    (clear error on submit, the loop itself keeps serving) and a
    hot-swap install clears it."""
    import repro.serve.loop as loop_mod
    fam = _binary_family(32, 5, seed=4)
    real = margins_dense

    def broken(bank, X, **kw):
        raise RuntimeError("wedged scorer")

    with ServeLoop(fam, buckets=(1,), default_budget_s=0.05,
                   batch_retries=0, quarantine_after=2) as loop:
        x = RNG.standard_normal(32).astype(np.float32)
        monkeypatch.setattr(loop_mod, "margins_dense", broken)
        for _ in range(2):                      # two one-request batches
            with pytest.raises(RuntimeError, match="wedged"):
                loop.submit(x).result(timeout=30)
        st = loop.stats()["models"]["default"]
        assert st["failed_batches"] == 2
        assert st["consecutive_failures"] == 2
        assert st["quarantined"]
        from repro.serve.loop import SlotQuarantined
        with pytest.raises(SlotQuarantined, match="quarantined after 2"):
            loop.submit(x)
        # the model is sick, not the loop: install a replacement...
        monkeypatch.setattr(loop_mod, "margins_dense", real)
        ticket = loop.swap(model=_binary_family(32, 7, seed=5))
        assert ticket.installed.wait(timeout=30)
        # ...and the slot serves again
        r = loop.submit(x).result(timeout=30)
        st = loop.stats()["models"]["default"]
        assert not st["quarantined"]
        assert st["consecutive_failures"] == 0
        assert r.version == 2


def test_failure_streak_resets_on_success(monkeypatch):
    import repro.serve.loop as loop_mod
    fam = _binary_family(24, 4, seed=6)
    real = margins_dense
    fail_next = {"on": True}

    def sometimes(bank, X, **kw):
        if fail_next["on"]:
            raise RuntimeError("blip")
        return real(bank, X, **kw)

    with ServeLoop(fam, buckets=(1,), default_budget_s=0.05,
                   batch_retries=0, quarantine_after=2) as loop:
        x = RNG.standard_normal(24).astype(np.float32)
        monkeypatch.setattr(loop_mod, "margins_dense", sometimes)
        with pytest.raises(RuntimeError):
            loop.submit(x).result(timeout=30)
        fail_next["on"] = False
        loop.submit(x).result(timeout=30)       # success resets the streak
        fail_next["on"] = True
        with pytest.raises(RuntimeError):
            loop.submit(x).result(timeout=30)
        st = loop.stats()["models"]["default"]
        assert st["failed_batches"] == 2        # total failures kept
        assert st["consecutive_failures"] == 1  # but the STREAK reset
        assert not st["quarantined"]


def test_quarantine_disabled_and_param_validation(monkeypatch):
    import repro.serve.loop as loop_mod
    fam = _binary_family(16, 3, seed=8)

    def broken(bank, X, **kw):
        raise RuntimeError("always down")

    with ServeLoop(fam, buckets=(1,), default_budget_s=0.05,
                   batch_retries=0, quarantine_after=None) as loop:
        x = RNG.standard_normal(16).astype(np.float32)
        monkeypatch.setattr(loop_mod, "margins_dense", broken)
        for _ in range(4):                      # never quarantines
            with pytest.raises(RuntimeError):
                loop.submit(x).result(timeout=30)
        assert not loop.stats()["models"]["default"]["quarantined"]
    with pytest.raises(ValueError, match="batch_retries"):
        ServeLoop(fam, buckets=(1,), batch_retries=-1)
    with pytest.raises(ValueError, match="quarantine_after"):
        ServeLoop(fam, buckets=(1,), quarantine_after=0)
