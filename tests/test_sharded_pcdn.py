"""Distributed PCDN == single-device PCDN (multi-device via subprocess).

These tests need >1 XLA device; jax fixes the device count at first init,
so they spawn a subprocess with XLA_FLAGS=--xla_force_host_platform_
device_count=8 (keeping every other test on 1 device, as required by the
assignment's dry-run isolation rule).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import jax, numpy as np
from repro.core.sharded import ShardedPCDNConfig, solve_sharded
from repro.core import make_problem, PCDNConfig, solve
from repro.data import make_classification

X, y, _ = make_classification(512, 256, sparsity=0.7, corr=0.4, seed=3)

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = ShardedPCDNConfig(P_local=16, c=1.0, data_axes=("data",))
w, f, conv, k, hist = solve_sharded(X, y, mesh, cfg, max_outer=40)
assert conv, "sharded PCDN must converge"
assert all(b <= a + 1e-4 for a, b in zip(hist["objective"],
                                         hist["objective"][1:])), "monotone"

prob = make_problem(X, y, c=1.0)
res = solve(prob, PCDNConfig(P=64, max_outer=40))
rel = abs(f - res.objective) / abs(res.objective)
assert rel < 1e-4, (f, res.objective)

# padded-CSC sparse layout: identical collective schedule, same answer
ws, fs, convs, ks, _ = solve_sharded(X, y, mesh, cfg, max_outer=40,
                                     layout="padded_csc")
assert convs, "sparse sharded PCDN must converge"
assert abs(fs - res.objective) / abs(res.objective) < 1e-4, (fs,
                                                            res.objective)

# multi-pod (3-axis) mesh
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg3 = ShardedPCDNConfig(P_local=32, c=1.0, data_axes=("pod", "data"))
w3, f3, conv3, k3, _ = solve_sharded(X, y, mesh3, cfg3, max_outer=40)
assert conv3
assert abs(f3 - res.objective) / abs(res.objective) < 1e-4

# multi-pod sparse
w4, f4, conv4, k4, _ = solve_sharded(X, y, mesh3, cfg3, max_outer=40,
                                     layout="padded_csc")
assert conv4
assert abs(f4 - res.objective) / abs(res.objective) < 1e-4
print("SHARDED_OK")
"""


@pytest.mark.slow
def test_sharded_pcdn_matches_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "SHARDED_OK" in out.stdout, out.stdout + out.stderr


MOE_SCRIPT = r"""
import jax
from repro.configs import get_config
from repro.models.transformer import Model
from repro.launch.specs import train_batch_specs

mesh1 = jax.make_mesh((1, 1), ("data", "model"))
for arch, shape in [("deepseek-moe-16b", (2, 4)), ("grok-1-314b", (1, 8)),
                    ("grok-1-314b", (2, 2))]:
    cfg = get_config(arch, reduced=True)
    m1 = Model(cfg, mesh1)
    params = m1.init_params(jax.random.PRNGKey(0))
    batch = train_batch_specs(cfg, batch=4, seq=16, concrete=True, seed=2)
    ref = float(m1.loss_fn(params, batch))
    meshN = jax.make_mesh(shape, ("data", "model"))
    mN = Model(cfg, meshN)
    pN = mN.shard_params(params)
    lossN = float(jax.jit(mN.loss_fn)(pN, batch))
    assert abs(ref - lossN) < 1e-4, (arch, shape, ref, lossN)
print("MOE_OK")
"""


@pytest.mark.slow
def test_sharded_moe_matches_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    out = subprocess.run([sys.executable, "-c", MOE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "MOE_OK" in out.stdout, out.stdout + out.stderr


DENSE_SCRIPT = r"""
import jax
from repro.configs import get_config
from repro.models.transformer import Model
from repro.launch.specs import train_batch_specs

mesh1 = jax.make_mesh((1, 1), ("data", "model"))
for arch in ["yi-6b", "recurrentgemma-2b", "falcon-mamba-7b",
             "whisper-small"]:
    cfg = get_config(arch, reduced=True)
    m1 = Model(cfg, mesh1)
    params = m1.init_params(jax.random.PRNGKey(0))
    batch = train_batch_specs(cfg, batch=4, seq=16, concrete=True, seed=2)
    ref = float(m1.loss_fn(params, batch))
    meshN = jax.make_mesh((2, 4), ("data", "model"))
    mN = Model(cfg, meshN)
    pN = mN.shard_params(params)
    lossN = float(jax.jit(mN.loss_fn)(pN, batch))
    assert abs(ref - lossN) < 1e-4, (arch, ref, lossN)
print("DENSE_OK")
"""


@pytest.mark.slow
def test_sharded_dense_families_match_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    out = subprocess.run([sys.executable, "-c", DENSE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "DENSE_OK" in out.stdout, out.stdout + out.stderr
