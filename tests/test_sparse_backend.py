"""Dense vs padded-CSC DesignMatrix backend equivalence (DESIGN.md §7).

Property-style over a grid of shapes/losses/sparsities: every problem
oracle (margins, bundle_grad_hess, full_grad, kkt_violation,
column_norms_sq) and full solver trajectories must agree between the two
backends to fp32 tolerance, including the ragged last bundle, empty
columns, and the Pallas kernel path. Plus the libsvm layout round-trips.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (DenseDesign, PCDNConfig, PaddedCSCDesign,
                        cdn_config, make_problem, scdn, solve, tron)
from repro.core.design_matrix import SparseSlab, as_design, padded_csc_arrays
from repro.data import make_classification
from repro.data.libsvm import (CSRMatrix, csr_to_padded_csc, load_libsvm,
                               save_libsvm)


def _sparse_X(s, n, sparsity=0.95, seed=0, empty_cols=()):
    X, y, _ = make_classification(s, n, sparsity=sparsity, corr=0.3,
                                  seed=seed)
    for j in empty_cols:
        X[:, j] = 0.0
    return X, y


def _pair(X, y, c=1.0, loss="logistic", l2=0.0):
    pd = make_problem(X, y, c=c, loss=loss, elastic_net_l2=l2)
    ps = make_problem(X, y, c=c, loss=loss, elastic_net_l2=l2,
                      layout="padded_csc")
    return pd, ps


CASES = [
    # (s, n, sparsity, loss, l2, empty_cols)
    (64, 40, 0.9, "logistic", 0.0, ()),
    (128, 96, 0.99, "logistic", 0.0, (0, 17, 95)),
    (96, 50, 0.95, "squared_hinge", 0.0, ()),
    (80, 33, 0.9, "logistic", 0.3, (32,)),   # l2 + last column empty
]


@pytest.mark.parametrize("s,n,sparsity,loss,l2,empty", CASES)
def test_oracles_agree(s, n, sparsity, loss, l2, empty):
    X, y = _sparse_X(s, n, sparsity, seed=s + n, empty_cols=empty)
    pd, ps = _pair(X, y, loss=loss, l2=l2)
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    w = w * (rng.random(n) < 0.5)  # mixed signs + exact zeros for KKT

    zd, zs = pd.margins(w), ps.margins(w)
    np.testing.assert_allclose(zd, zs, atol=1e-5)
    np.testing.assert_allclose(pd.full_grad(zd, w), ps.full_grad(zs, w),
                               atol=1e-4)
    np.testing.assert_allclose(pd.kkt_violation(w), ps.kkt_violation(w),
                               atol=1e-4)
    np.testing.assert_allclose(pd.column_norms_sq(), ps.column_norms_sq(),
                               atol=1e-4)


@pytest.mark.parametrize("s,n,sparsity,loss,l2,empty", CASES)
def test_bundle_grad_hess_agree(s, n, sparsity, loss, l2, empty):
    """Includes the ragged bundle: P does not divide n, sentinel idx == n."""
    X, y = _sparse_X(s, n, sparsity, seed=7, empty_cols=empty)
    pd, ps = _pair(X, y, loss=loss, l2=l2)
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    z = pd.margins(w)
    P = 16
    idx = jnp.concatenate([
        jnp.asarray(rng.permutation(n)[:P - 3], jnp.int32),
        jnp.full((3,), n, jnp.int32)])          # ragged: 3 sentinel slots
    w_B = jnp.where(idx < n, w[jnp.minimum(idx, n - 1)], 0.0)
    gd, hd = pd.bundle_grad_hess(z, pd.design.gather_slab(idx), w_B)
    gs, hs = ps.bundle_grad_hess(z, ps.design.gather_slab(idx), w_B)
    np.testing.assert_allclose(gd, gs, atol=1e-4)
    np.testing.assert_allclose(hd, hs, atol=1e-4)
    # sentinel slots contribute nothing on either backend
    np.testing.assert_allclose(gd[-3:], l2 * w_B[-3:], atol=1e-6)


def test_pcdn_trajectories_identical():
    """Same seed => same iterate trajectory to fp tolerance, ragged P."""
    X, y = _sparse_X(96, 70, 0.95, seed=3, empty_cols=(5,))
    pd, ps = _pair(X, y)
    for ls in ("batched", "backtracking"):
        cfg = PCDNConfig(P=32, max_outer=15, seed=4, ls_kind=ls)  # 32 !| 70
        rd, rs = solve(pd, cfg), solve(ps, cfg)
        np.testing.assert_allclose(rd.history.objective,
                                   rs.history.objective, rtol=1e-4)
        np.testing.assert_allclose(rd.w, rs.w, atol=1e-4)


def test_pcdn_kernel_path_matches_jnp_path_sparse():
    X, y = _sparse_X(128, 64, 0.95, seed=5)
    _, ps = _pair(X, y)
    r_jnp = solve(ps, PCDNConfig(P=32, max_outer=8, seed=0,
                                 use_kernels=False))
    r_ker = solve(ps, PCDNConfig(P=32, max_outer=8, seed=0,
                                 use_kernels=True))
    np.testing.assert_allclose(r_jnp.history.objective,
                               r_ker.history.objective, rtol=1e-4)


def test_cdn_scdn_tron_run_on_sparse_backend():
    X, y = _sparse_X(80, 40, 0.9, seed=6)
    pd, ps = _pair(X, y)
    rd = solve(pd, cdn_config(max_outer=5, seed=1))
    rs = solve(ps, cdn_config(max_outer=5, seed=1))
    np.testing.assert_allclose(rd.history.objective, rs.history.objective,
                               rtol=1e-4)
    sd = scdn.solve(pd, scdn.SCDNConfig(P_bar=4, max_rounds=5, seed=1))
    ss = scdn.solve(ps, scdn.SCDNConfig(P_bar=4, max_rounds=5, seed=1))
    np.testing.assert_allclose(sd.history["objective"],
                               ss.history["objective"], rtol=1e-4)
    td = tron.solve(pd, tron.TRONConfig(max_outer=10))
    t_s = tron.solve(ps, tron.TRONConfig(max_outer=10))
    np.testing.assert_allclose(td.objective, t_s.objective, rtol=1e-4)


def test_sparse_backend_never_exposes_dense_X():
    X, y = _sparse_X(32, 16, 0.9, seed=8)
    _, ps = _pair(X, y)
    assert isinstance(ps.design, PaddedCSCDesign)
    with pytest.raises(TypeError):
        _ = ps.X


def test_empty_column_and_all_zero_row():
    X, y = _sparse_X(40, 20, 0.9, seed=9, empty_cols=(0, 19))
    X[7, :] = 0.0
    pd, ps = _pair(X, y)
    res_d = solve(pd, PCDNConfig(P=8, max_outer=10, seed=0))
    res_s = solve(ps, PCDNConfig(P=8, max_outer=10, seed=0))
    np.testing.assert_allclose(res_d.history.objective,
                               res_s.history.objective, rtol=1e-4)
    # empty columns must stay at exactly 0 (they cannot reduce the loss)
    assert float(jnp.abs(res_s.w[0])) == 0.0
    assert float(jnp.abs(res_s.w[19])) == 0.0


# -- converters / data layer --------------------------------------------------

def _ragged_csr(seed=0):
    """Rows with wildly different nnz (incl. an empty row/column)."""
    rng = np.random.default_rng(seed)
    s, n = 23, 17
    X = np.zeros((s, n), np.float32)
    for i in range(s):
        k = rng.integers(0, n)          # 0..n-1 nnz in this row
        cols = rng.choice(n, size=k, replace=False)
        X[i, cols] = rng.standard_normal(k).astype(np.float32)
    X[:, 3] = 0.0
    X[11, :] = 0.0
    rows, cols = np.nonzero(X)
    vals = X[rows, cols]
    indptr = np.concatenate(
        [[0], np.cumsum(np.bincount(rows, minlength=s))]).astype(np.int64)
    return CSRMatrix(vals, cols.astype(np.int32), indptr, (s, n)), X


def test_csr_to_dense_vectorized_round_trip():
    csr, X = _ragged_csr()
    np.testing.assert_array_equal(csr.to_dense(), X)


def test_csr_padded_csc_round_trip_ragged():
    csr, X = _ragged_csr(seed=3)
    pcsc = csr_to_padded_csc(csr)
    assert pcsc.k_max == csr.max_col_nnz()
    design = as_design(pcsc)
    np.testing.assert_allclose(np.asarray(design.to_dense()), X, atol=0)
    # direct from_csr agrees with the two-step conversion
    d2 = PaddedCSCDesign.from_csr(csr.data, csr.indices, csr.indptr,
                                  csr.shape)
    np.testing.assert_array_equal(np.asarray(d2.col_rows),
                                  np.asarray(design.col_rows))


def test_k_max_overflow_raises():
    csr, _ = _ragged_csr(seed=4)
    with pytest.raises(ValueError):
        padded_csc_arrays(csr.data, csr.indices, csr.indptr, csr.shape,
                          k_max=1)


def test_load_libsvm_padded_csc_layout(tmp_path):
    rng = np.random.default_rng(5)
    X = ((rng.random((30, 12)) < 0.3) *
         rng.standard_normal((30, 12))).astype(np.float32)
    y = np.where(rng.random(30) < 0.5, 1.0, -1.0).astype(np.float32)
    p = str(tmp_path / "t.svm")
    save_libsvm(p, X, y)
    pcsc, y2 = load_libsvm(p, n_features=12, layout="padded_csc")
    prob = make_problem(pcsc, y2, c=1.0)
    dense_prob = make_problem(*load_libsvm(p, n_features=12), c=1.0)
    np.testing.assert_allclose(prob.objective(jnp.ones(12)),
                               dense_prob.objective(jnp.ones(12)),
                               rtol=1e-5)


def test_dense_design_is_default_and_back_compat():
    X, y = _sparse_X(16, 8, 0.5, seed=10)
    prob = make_problem(X, y, c=1.0)
    assert isinstance(prob.design, DenseDesign)
    assert prob.X.shape == (16, 8)       # legacy dense accessor still works
    # legacy raw-slab call signature still accepted
    z = prob.margins(jnp.zeros(8))
    g, h = prob.bundle_grad_hess(z, prob.X, jnp.zeros(8))
    assert g.shape == (8,) and h.shape == (8,)


def test_gather_slab_types():
    X, y = _sparse_X(16, 8, 0.5, seed=11)
    _, ps = _pair(X, y)
    slab = ps.design.gather_slab(jnp.arange(4, dtype=jnp.int32))
    assert isinstance(slab, SparseSlab)
    assert slab.rows.shape == (4, ps.design.k_max)
