"""End-to-end behaviour tests: the paper's full pipeline on synthetic data.

Covers: PCDN convergence + monotone descent at extreme parallelism (the
paper's core claim), solver agreement at the optimum (PCDN = CDN = TRON),
and SCDN's divergence under correlation.
"""
import numpy as np
import pytest

from repro.core import (PCDNConfig, cdn_config, make_problem, scdn, solve,
                        tron)
from repro.core.scdn import SCDNConfig
from repro.data import make_classification


@pytest.fixture(scope="module")
def problem():
    X, y, _ = make_classification(400, 160, sparsity=0.7, corr=0.4, seed=7)
    return make_problem(X, y, c=1.0, loss="logistic")


def test_pcdn_converges_and_is_monotone(problem):
    res = solve(problem, PCDNConfig(P=32, max_outer=150, tol_kkt=1e-3))
    assert res.converged
    diffs = np.diff(res.history.objective)
    assert np.all(diffs <= 1e-4), "objective must be nonincreasing (Lemma 1c)"


def test_full_parallelism_still_converges(problem):
    """P = n: maximal parallelism, guaranteed convergence (Thm 3 / A.5)."""
    n = problem.n_features
    res = solve(problem, PCDNConfig(P=n, max_outer=300, tol_kkt=1e-3))
    assert res.converged
    assert np.all(np.diff(res.history.objective) <= 1e-4)


def test_solver_agreement_at_optimum(problem):
    """PCDN, CDN and TRON all minimize the same objective."""
    f_pcdn = solve(problem, PCDNConfig(P=16, max_outer=200,
                                       tol_kkt=1e-4)).objective
    f_cdn = solve(problem, cdn_config(max_outer=200, tol_kkt=1e-4)).objective
    f_tron = tron.solve(problem,
                        tron.TRONConfig(tol_kkt=1e-4)).objective
    assert abs(f_pcdn - f_cdn) / abs(f_cdn) < 1e-4
    assert abs(f_pcdn - f_tron) / abs(f_tron) < 1e-4


def test_svm_loss_end_to_end(problem):
    prob = make_problem(np.asarray(problem.X), np.asarray(problem.y),
                        c=0.5, loss="squared_hinge")
    res = solve(prob, PCDNConfig(P=32, max_outer=200, tol_kkt=1e-2))
    assert res.converged
    assert np.all(np.diff(res.history.objective) <= 1e-3)


def test_scdn_diverges_under_correlation_pcdn_does_not():
    """Reproduces the paper's core comparison (section 2.2 / 5.3)."""
    X, y, _ = make_classification(300, 200, sparsity=0.0, corr=0.95,
                                  seed=2, row_normalize=False)
    prob = make_problem(X, y, c=1.0)
    r_scdn = scdn.solve(prob, SCDNConfig(P_bar=64, max_rounds=30))
    assert r_scdn.diverged
    r_pcdn = solve(prob, PCDNConfig(P=64, max_outer=30))
    assert np.all(np.diff(r_pcdn.history.objective) <= 1e-3)


def test_sparse_solution_recovered(problem):
    res = solve(problem, PCDNConfig(P=32, max_outer=150, tol_kkt=1e-3))
    nnz = int(res.history.nnz[-1])
    assert 0 < nnz < problem.n_features, "l1 must induce sparsity"


def test_elastic_net_extension(problem):
    prob = make_problem(np.asarray(problem.X), np.asarray(problem.y),
                        c=1.0, elastic_net_l2=0.5)
    res = solve(prob, PCDNConfig(P=32, max_outer=150, tol_kkt=1e-3))
    assert res.converged
