"""Property tests for the paper's theory (Lemmas 1a-1c, Theorems 2-3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import PCDNConfig, make_problem, solve
from repro.core.direction import (delta_decrement, delta_upper_bound,
                                  newton_direction)
from repro.core.linesearch import ArmijoParams
from repro.core.problem import expected_max_of_sample
from repro.data import make_classification


# -- Lemma 1(a): E[max of size-P subset] properties --------------------------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0.01, 100.0), min_size=3, max_size=40),
       st.data())
def test_lemma1a_monotone_in_P(lams, data):
    lam = np.sort(np.asarray(lams))
    n = lam.shape[0]
    P = data.draw(st.integers(1, n - 1))
    f_P = expected_max_of_sample(lam, P)
    f_P1 = expected_max_of_sample(lam, P + 1)
    assert f_P1 >= f_P - 1e-9, "E[max] must be monotone increasing in P"
    g_P = f_P / P
    g_P1 = f_P1 / (P + 1)
    assert g_P1 <= g_P + 1e-9, "E[max]/P must be monotone decreasing in P"


def test_lemma1a_constant_when_equal():
    lam = np.full(20, 3.7)
    for P in (1, 5, 20):
        assert abs(expected_max_of_sample(lam, P) - 3.7) < 1e-12


def test_lemma1a_matches_monte_carlo():
    rng = np.random.default_rng(0)
    lam = np.sort(rng.uniform(0.1, 5.0, size=12))
    P = 4
    analytic = expected_max_of_sample(lam, P)
    draws = [lam[rng.choice(12, P, replace=False)].max()
             for _ in range(20000)]
    assert abs(analytic - np.mean(draws)) < 0.02


# -- Lemma 1(b): Hessian diagonal bounds --------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(["logistic",
                                                "squared_hinge"]))
def test_lemma1b_hessian_bounds(seed, loss_name):
    X, y, _ = make_classification(60, 20, sparsity=0.3, seed=seed % 100)
    c = 1.5
    prob = make_problem(X, y, c=c, loss=loss_name)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal(20) * 0.5, jnp.float32)
    z = prob.margins(w)
    _, h = prob.bundle_grad_hess(z, prob.X, w)
    theta = prob.loss.theta
    upper = theta * c * np.asarray(prob.column_norms_sq())
    assert np.all(np.asarray(h) <= upper + 1e-4), \
        "Eq. 14: hess_jj <= theta*c*(X^T X)_jj"
    assert np.all(np.asarray(h) > 0), "hessian floor must keep h positive"


# -- Lemma 1(c): Delta upper bound + monotone descent -------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.0, 0.9))
def test_lemma1c_delta_bound(seed, gamma):
    rng = np.random.default_rng(seed)
    P = 8
    g = jnp.asarray(rng.standard_normal(P), jnp.float32)
    h = jnp.asarray(rng.uniform(0.1, 3.0, P), jnp.float32)
    w = jnp.asarray(rng.standard_normal(P), jnp.float32)
    d = newton_direction(g, h, w)
    Delta = delta_decrement(g, h, w, d, gamma)
    bound = delta_upper_bound(h, d, gamma)
    assert float(Delta) <= float(bound) + 1e-5, \
        "Eq. 16: Delta <= (gamma-1) d^T H d"
    assert float(bound) <= 1e-6, "bound must be nonpositive"


# -- Theorem 2: line-search step bound ----------------------------------------

def test_theorem2_expected_linesearch_steps():
    """Mean observed q^t must respect the Thm-2 upper bound."""
    X, y, _ = make_classification(300, 120, sparsity=0.5, corr=0.5, seed=3)
    prob = make_problem(X, y, c=1.0)
    ap = ArmijoParams()
    lam = np.asarray(prob.column_norms_sq(), dtype=np.float64)
    theta, c = 0.25, 1.0
    # empirical lower bound h_min over iterates is unknown a priori; use the
    # floor implied by tau in (tau_min, 1-tau_min) over observed margins,
    # conservatively 1e-4 * c * min colnorm
    h_lo = 1e-4 * c * lam.min()
    for P in (8, 60, 120):
        res = solve(prob, PCDNConfig(P=P, max_outer=10))
        e_lam = expected_max_of_sample(np.sort(lam), P)
        bound = (1 + np.log(theta * c / (2 * h_lo * (1 - ap.sigma))) /
                 np.log(1 / ap.beta)
                 + 0.5 * np.log(P) / np.log(1 / ap.beta)
                 + np.log(e_lam) / np.log(1 / ap.beta))
        mean_q = res.history.ls_steps.mean()
        assert mean_q <= bound, (P, mean_q, bound)


def test_theorem2_steps_grow_with_P():
    X, y, _ = make_classification(300, 120, sparsity=0.3, corr=0.6, seed=4)
    prob = make_problem(X, y, c=1.0)
    qs = []
    for P in (1, 16, 120):
        res = solve(prob, PCDNConfig(P=P, max_outer=8))
        qs.append(res.history.ls_steps.mean())
    assert qs[0] <= qs[1] + 0.2 and qs[1] <= qs[2] + 0.2, qs


# -- Theorem 3 / Eq. 19: iteration count decreases with P ---------------------

def test_iteration_count_decreases_with_P():
    """Thm 3 counts INNER (bundle) iterations: T = n_outer * ceil(n/P)."""
    X, y, _ = make_classification(400, 150, sparsity=0.5, corr=0.3, seed=5)
    n = 150
    prob = make_problem(X, y, c=1.0)
    f_star = solve(prob, PCDNConfig(P=n, max_outer=400,
                                    tol_kkt=1e-6)).objective
    eps = 1e-3

    def inner_iters_to_eps(P):
        res = solve(prob, PCDNConfig(P=P, max_outer=400, tol_kkt=0.0,
                                     tol_rel_obj=eps), f_star=f_star)
        assert res.converged
        return res.n_outer * (-(-n // P))

    t1, t16, t150 = (inner_iters_to_eps(P) for P in (1, 16, n))
    assert t16 <= t1, (t1, t16)
    assert t150 <= t16, (t16, t150)
